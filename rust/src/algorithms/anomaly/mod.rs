//! Non-parametric anomaly detection (paper §4.2).
//!
//! A point is anomalous iff fewer than `threshold` points of the dataset
//! lie within `radius` of it. The tree-accelerated test keeps two running
//! quantities while recursing — `found` (points proven within range) and
//! `possible` (an upper bound on how many could still be) — and prunes
//! with the paper's four rules:
//!
//! 1. node entirely inside the query ball  → add its count wholesale;
//! 2. node entirely outside                → subtract from the bound;
//! 3. `found > threshold`                  → early exit: NOT an anomaly;
//! 4. `possible < threshold`               → early exit: IS an anomaly.

use crate::metrics::{block, Space};
use crate::tree::{MetricTree, NodeId};

/// Parameters of the anomaly test.
#[derive(Clone, Copy, Debug)]
pub struct AnomalyParams {
    /// Neighborhood radius r.
    pub radius: f64,
    /// A point is an anomaly when |{x : D(x,q) ≤ r}| < threshold.
    /// The query point itself is in the dataset and is counted (both
    /// paths are consistent about this).
    pub threshold: u64,
}

/// Naive test: scan all points, aborting as soon as `threshold` neighbors
/// are found (this is what makes the paper's "regular" column ≈ R²/2
/// instead of R² for non-anomalous data).
pub fn naive_is_anomaly(space: &Space, q: usize, params: &AnomalyParams) -> bool {
    let mut found = 0u64;
    for p in 0..space.n() {
        if p % block::SCAN_CHUNK == 0 {
            space.checkpoint();
        }
        if space.dist(p, q) <= params.radius {
            found += 1;
            if found >= params.threshold {
                space.obs().leaf_rows(crate::ids::u64_from_usize(p + 1));
                return false;
            }
        }
    }
    space.obs().leaf_rows(crate::ids::u64_from_usize(space.n()));
    true
}

/// Tree-accelerated test for a query that is a datapoint.
pub fn tree_is_anomaly(
    space: &Space,
    tree: &MetricTree,
    q: usize,
    params: &AnomalyParams,
) -> bool {
    let mut qrow = vec![0f32; space.dim()];
    space.fill_row(q, &mut qrow);
    let q_sq = space.data.sqnorm(q);
    tree_is_anomaly_vec(space, tree, &qrow, q_sq, params)
}

/// Tree-accelerated test for an arbitrary query vector.
pub fn tree_is_anomaly_vec(
    space: &Space,
    tree: &MetricTree,
    qrow: &[f32],
    q_sq: f64,
    params: &AnomalyParams,
) -> bool {
    let mut found = 0u64;
    let mut possible = tree.root_node().count as u64;
    // The f32 filter (if the tier is on) accelerates only the blocked
    // leaf branch below, where no early exit can fire: a pruned row
    // provably has d > radius, exactly the rows whose `possible -= 1`
    // outcome is already known — so verdicts and counts match tier-off.
    let filter = block::F32Filter::new(tree.arena(), qrow);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    // The root's pivot distance is computed here and *counted* by
    // `recurse` on entry — every visited node pays for its pivot
    // distance exactly once (the same evaluation also serves as the
    // parent's child-ordering key, so it is never recomputed).
    let root_node = tree.root_node();
    let d_root = dist_vec_uncounted(space, qrow, q_sq, &root_node.pivot, root_node.pivot_sq);
    let verdict = recurse(
        space,
        tree,
        tree.root,
        d_root,
        qrow,
        q_sq,
        params,
        0,
        &mut found,
        &mut possible,
        &filter,
        &mut dists,
        &mut frows,
    );
    match verdict {
        Some(v) => v,
        // Exhausted the tree without an early exit: exact count known.
        None => found < params.threshold,
    }
}

/// Depth-first descent, closer child first. Returns Some(verdict) on an
/// early exit (rules 3/4), None to continue.
///
/// `d_pivot` is the query's distance to this node's pivot, computed by
/// the caller (it doubles as the child-ordering key there) and accounted
/// here: one counted pivot distance per visited node, same as computing
/// it on entry, but without the former duplicate uncounted evaluation in
/// the parent.
#[allow(clippy::too_many_arguments)]
fn recurse(
    space: &Space,
    tree: &MetricTree,
    node_id: NodeId,
    d_pivot: f64,
    qrow: &[f32],
    q_sq: f64,
    params: &AnomalyParams,
    depth: usize,
    found: &mut u64,
    possible: &mut u64,
    filter: &Option<block::F32Filter>,
    dists: &mut Vec<f64>,
    frows: &mut Vec<u32>,
) -> Option<bool> {
    let node = tree.node(node_id);
    space.checkpoint();
    space.count_bulk(1);
    let obs = space.obs();
    obs.visit(depth);

    // Rule 1: whole node within range.
    if d_pivot + node.radius <= params.radius {
        *found += node.count as u64;
        obs.prune(crate::obs::PruneRule::Triangle);
        if *found >= params.threshold {
            obs.prune(crate::obs::PruneRule::Rule3);
            return Some(false); // rule 3
        }
        return None;
    }
    // Rule 2: whole node out of range.
    if d_pivot - node.radius > params.radius {
        *possible -= node.count as u64;
        obs.prune(crate::obs::PruneRule::Triangle);
        if *possible < params.threshold {
            obs.prune(crate::obs::PruneRule::Rule4);
            return Some(true); // rule 4
        }
        return None;
    }

    match node.children {
        None => {
            let arena = tree.arena();
            let rows = tree.node_rows(node_id);
            let leaf = rows.len() as u64;
            if *found + leaf < params.threshold
                && *possible >= leaf
                && *possible - leaf >= params.threshold
            {
                // Neither rule 3 nor rule 4 can trigger inside this leaf
                // no matter how its points fall, so the scalar scan would
                // visit every point — the contiguous kernel over the
                // leaf's arena slab is safe and its bulk accounting
                // matches the pointwise count exactly.
                obs.leaf_rows(leaf);
                match filter {
                    Some(f) => {
                        block::dists_contig_to_vec_f32(
                            arena, rows, qrow, q_sq, f, params.radius, frows, dists,
                        );
                        // Every pruned row provably has d > radius: the
                        // tier-off scan would take its `possible -= 1`
                        // branch, so settle them in one subtraction.
                        *possible -= leaf - frows.len() as u64;
                        obs.prune_n(
                            crate::obs::PruneRule::F32Reject,
                            leaf - crate::ids::u64_from_usize(frows.len()),
                        );
                        for &d in dists.iter() {
                            if d <= params.radius {
                                *found += 1;
                            } else {
                                *possible -= 1;
                            }
                        }
                    }
                    None => {
                        block::dists_contig_to_vec(arena, rows, qrow, q_sq, dists);
                        for &d in dists.iter() {
                            if d <= params.radius {
                                *found += 1;
                            } else {
                                *possible -= 1;
                            }
                        }
                    }
                }
                return None;
            }
            // Early-exit-eligible leaf: pointwise over the same arena
            // rows (sequential reads; same values, same per-point
            // counting, same exit points as the gather scan).
            let mut scanned = 0u64;
            for r in rows {
                scanned += 1;
                let d = arena.dist_to_vec(r, qrow, q_sq);
                if d <= params.radius {
                    *found += 1;
                    if *found >= params.threshold {
                        obs.leaf_rows(scanned);
                        obs.prune(crate::obs::PruneRule::Rule3);
                        return Some(false); // rule 3
                    }
                } else {
                    *possible -= 1;
                    if *possible < params.threshold {
                        obs.leaf_rows(scanned);
                        obs.prune(crate::obs::PruneRule::Rule4);
                        return Some(true); // rule 4
                    }
                }
            }
            obs.leaf_rows(scanned);
            None
        }
        Some((a, b)) => {
            // Closer child first maximizes early rule-3 exits for normal
            // points (the common case). These evaluations are handed down
            // and counted by each child on entry — computed once, counted
            // once.
            let (na, nb) = (tree.node(a), tree.node(b));
            let da = dist_vec_uncounted(space, qrow, q_sq, &na.pivot, na.pivot_sq);
            let db = dist_vec_uncounted(space, qrow, q_sq, &nb.pivot, nb.pivot_sq);
            let ((first, d_first), (second, d_second)) =
                if da <= db { ((a, da), (b, db)) } else { ((b, db), (a, da)) };
            if let Some(v) = recurse(
                space, tree, first, d_first, qrow, q_sq, params, depth + 1, found, possible,
                filter, dists, frows,
            ) {
                return Some(v);
            }
            recurse(
                space, tree, second, d_second, qrow, q_sq, params, depth + 1, found, possible,
                filter, dists, frows,
            )
        }
    }
}

/// Pivot distance via the cached-norm dot formula. Accounting happens in
/// `recurse` (one `count_bulk(1)` per visited node), not here: each
/// evaluation serves both as the parent's ordering key and as the child's
/// bound, and must be paid for exactly once.
#[inline]
fn dist_vec_uncounted(space: &Space, a: &[f32], a_sq: f64, b: &[f32], b_sq: f64) -> f64 {
    use crate::metrics::{dense_dot, dense_l1, Metric};
    match space.metric {
        Metric::Euclidean => {
            // pallas-lint: allow(uncounted-dist, counted once per visited node in recurse)
            let d2 = a_sq + b_sq - 2.0 * dense_dot(a, b);
            d2.max(0.0).sqrt()
        }
        // pallas-lint: allow(uncounted-dist, counted once per visited node in recurse)
        Metric::L1 => dense_l1(a, b),
    }
}

/// Result of sweeping the anomaly test over every datapoint.
#[derive(Clone, Debug)]
pub struct AnomalySweep {
    pub flags: Vec<bool>,
    pub n_anomalies: usize,
    pub dists: u64,
}

/// Run the naive detector over all points.
pub fn naive_sweep(space: &Space, params: &AnomalyParams) -> AnomalySweep {
    let before = space.dist_count();
    let flags: Vec<bool> = (0..space.n())
        .map(|q| naive_is_anomaly(space, q, params))
        .collect();
    let n_anomalies = flags.iter().filter(|&&f| f).count();
    AnomalySweep { flags, n_anomalies, dists: space.dist_count() - before }
}

/// Run the tree detector over all points.
pub fn tree_sweep(space: &Space, tree: &MetricTree, params: &AnomalyParams) -> AnomalySweep {
    let before = space.dist_count();
    let flags: Vec<bool> = (0..space.n())
        .map(|q| tree_is_anomaly(space, tree, q, params))
        .collect();
    let n_anomalies = flags.iter().filter(|&&f| f).count();
    AnomalySweep { flags, n_anomalies, dists: space.dist_count() - before }
}

/// Choose a radius that makes roughly `target_frac` of the points
/// anomalous at the given threshold — the paper's "interesting" regime
/// (§5: ≈10% anomalous). Estimated from a sample, binary-searching the
/// radius. Uncounted (experimental setup, not algorithm work).
pub fn calibrate_radius(
    space: &Space,
    threshold: u64,
    target_frac: f64,
    sample: usize,
    seed: u64,
) -> f64 {
    use crate::rng::Rng;
    let mut rng = Rng::new(seed);
    let n = space.n();
    let sample_ids: Vec<usize> = (0..sample.min(n)).map(|_| rng.below(n)).collect();
    // kth-nearest-neighbor distance of each sampled point, where
    // k = threshold: the radius at which the point stops being anomalous.
    let mut kth: Vec<f64> = sample_ids
        .iter()
        .map(|&q| {
            // pallas-lint: allow(uncounted-dist, calibration is experimental setup; documented uncounted)
            let mut ds: Vec<f64> = (0..n).map(|p| space.dist_uncounted(p, q)).collect();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ds[(threshold as usize).min(n - 1)]
        })
        .collect();
    kth.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Radius at the target quantile: points whose kth-NN distance exceeds
    // the radius are anomalous.
    let idx = ((1.0 - target_frac) * (kth.len() - 1) as f64).round() as usize;
    kth[idx.min(kth.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    /// A dense blob plus a few far-out points (the anomalies).
    fn blob_with_outliers(n_blob: usize, n_out: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for _ in 0..n_blob {
            rows.push(vec![rng.normal() as f32, rng.normal() as f32]);
        }
        for i in 0..n_out {
            let angle = i as f64;
            rows.push(vec![
                (100.0 * angle.cos() + rng.normal()) as f32,
                (100.0 * angle.sin() + rng.normal()) as f32,
            ]);
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn detects_planted_outliers() {
        let space = blob_with_outliers(500, 8, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let params = AnomalyParams { radius: 5.0, threshold: 10 };
        let sweep = tree_sweep(&space, &tree, &params);
        // All 8 planted outliers flagged; blob points not.
        for q in 500..508 {
            assert!(sweep.flags[q], "outlier {q} missed");
        }
        let blob_flagged = sweep.flags[..500].iter().filter(|&&f| f).count();
        assert_eq!(blob_flagged, 0, "{blob_flagged} blob points misflagged");
    }

    #[test]
    fn tree_matches_naive_exactly() {
        let space = blob_with_outliers(300, 5, 2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        for (radius, threshold) in [(2.0, 5), (5.0, 20), (0.5, 2), (50.0, 100)] {
            let params = AnomalyParams { radius, threshold };
            let a = naive_sweep(&space, &params);
            let b = tree_sweep(&space, &tree, &params);
            assert_eq!(a.flags, b.flags, "r={radius} t={threshold}");
        }
    }

    #[test]
    fn tree_saves_distances() {
        let space = blob_with_outliers(2000, 10, 3);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 30, ..Default::default() });
        let radius = calibrate_radius(&space, 20, 0.1, 30, 7);
        let params = AnomalyParams { radius, threshold: 20 };
        let a = naive_sweep(&space, &params);
        let b = tree_sweep(&space, &tree, &params);
        assert_eq!(a.flags, b.flags);
        assert!(
            b.dists * 2 < a.dists,
            "tree {} vs naive {} distances",
            b.dists,
            a.dists
        );
    }

    #[test]
    fn threshold_one_everything_normal() {
        // Every point is within radius 0 of itself → never anomalous at
        // threshold 1.
        let space = blob_with_outliers(100, 3, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let params = AnomalyParams { radius: 1e-9, threshold: 1 };
        let sweep = tree_sweep(&space, &tree, &params);
        assert_eq!(sweep.n_anomalies, 0);
    }

    #[test]
    fn huge_threshold_everything_anomalous() {
        let space = blob_with_outliers(100, 0, 5);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let params = AnomalyParams { radius: 0.5, threshold: 1000 };
        let a = naive_sweep(&space, &params);
        let b = tree_sweep(&space, &tree, &params);
        assert_eq!(a.n_anomalies, 100);
        assert_eq!(b.n_anomalies, 100);
    }

    #[test]
    fn calibration_hits_target_fraction() {
        let space = blob_with_outliers(800, 0, 6);
        let threshold = 15;
        let radius = calibrate_radius(&space, threshold, 0.1, 60, 8);
        let params = AnomalyParams { radius, threshold };
        let sweep = naive_sweep(&space, &params);
        let frac = sweep.n_anomalies as f64 / space.n() as f64;
        assert!(
            (0.02..0.3).contains(&frac),
            "calibrated fraction {frac} far from 0.1"
        );
    }

    /// Reference recursion in the *old* style: every visited node pays a
    /// counted pivot distance on entry, and the parent separately
    /// recomputes both children's pivot distances (uncounted) for
    /// ordering. The production path now threads the parent's evaluation
    /// down instead; flags and distance counts must be identical.
    #[allow(clippy::too_many_arguments)]
    fn reference_recurse(
        space: &Space,
        tree: &MetricTree,
        node_id: NodeId,
        qrow: &[f32],
        q_sq: f64,
        params: &AnomalyParams,
        found: &mut u64,
        possible: &mut u64,
        dists: &mut Vec<f64>,
    ) -> Option<bool> {
        let node = tree.node(node_id);
        space.count_bulk(1);
        let d_pivot = dist_vec_uncounted(space, qrow, q_sq, &node.pivot, node.pivot_sq);
        if d_pivot + node.radius <= params.radius {
            *found += node.count as u64;
            if *found >= params.threshold {
                return Some(false);
            }
            return None;
        }
        if d_pivot - node.radius > params.radius {
            *possible -= node.count as u64;
            if *possible < params.threshold {
                return Some(true);
            }
            return None;
        }
        match node.children {
            None => {
                let arena = tree.arena();
                let rows = tree.node_rows(node_id);
                let leaf = rows.len() as u64;
                if *found + leaf < params.threshold
                    && *possible >= leaf
                    && *possible - leaf >= params.threshold
                {
                    crate::metrics::block::dists_contig_to_vec(arena, rows, qrow, q_sq, dists);
                    for &d in dists.iter() {
                        if d <= params.radius {
                            *found += 1;
                        } else {
                            *possible -= 1;
                        }
                    }
                    return None;
                }
                for r in rows {
                    let d = arena.dist_to_vec(r, qrow, q_sq);
                    if d <= params.radius {
                        *found += 1;
                        if *found >= params.threshold {
                            return Some(false);
                        }
                    } else {
                        *possible -= 1;
                        if *possible < params.threshold {
                            return Some(true);
                        }
                    }
                }
                None
            }
            Some((a, b)) => {
                let (na, nb) = (tree.node(a), tree.node(b));
                let da = dist_vec_uncounted(space, qrow, q_sq, &na.pivot, na.pivot_sq);
                let db = dist_vec_uncounted(space, qrow, q_sq, &nb.pivot, nb.pivot_sq);
                let (first, second) = if da <= db { (a, b) } else { (b, a) };
                if let Some(v) = reference_recurse(
                    space, tree, first, qrow, q_sq, params, found, possible, dists,
                ) {
                    return Some(v);
                }
                reference_recurse(space, tree, second, qrow, q_sq, params, found, possible, dists)
            }
        }
    }

    fn reference_is_anomaly(
        space: &Space,
        tree: &MetricTree,
        q: usize,
        params: &AnomalyParams,
    ) -> bool {
        let mut qrow = vec![0f32; space.dim()];
        space.fill_row(q, &mut qrow);
        let q_sq = space.data.sqnorm(q);
        let mut found = 0u64;
        let mut possible = tree.root_node().count as u64;
        let mut dists = Vec::new();
        match reference_recurse(
            space, tree, tree.root, &qrow, q_sq, params, &mut found, &mut possible, &mut dists,
        ) {
            Some(v) => v,
            None => found < params.threshold,
        }
    }

    #[test]
    fn threaded_pivot_distance_matches_reference_exactly() {
        // The fix that threads d_pivot down the recursion must change
        // neither verdicts nor the eq.-6 distance accounting relative to
        // the recompute-at-entry reference, query by query.
        let space = blob_with_outliers(400, 6, 9);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 12, ..Default::default() });
        for (radius, threshold) in [(2.0, 5), (5.0, 20), (0.5, 2)] {
            let params = AnomalyParams { radius, threshold };
            for q in (0..space.n()).step_by(7) {
                space.reset_count();
                let want = reference_is_anomaly(&space, &tree, q, &params);
                let want_dists = space.dist_count();
                space.reset_count();
                let got = tree_is_anomaly(&space, &tree, q, &params);
                let got_dists = space.dist_count();
                assert_eq!(got, want, "q={q} r={radius} t={threshold}");
                assert_eq!(
                    got_dists, want_dists,
                    "q={q} r={radius} t={threshold}: accounting drifted"
                );
            }
        }
    }

    #[test]
    fn vec_query_api() {
        let space = blob_with_outliers(200, 2, 7);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let params = AnomalyParams { radius: 3.0, threshold: 5 };
        // Query at the blob center: not an anomaly.
        let q = vec![0.0f32, 0.0];
        assert!(!tree_is_anomaly_vec(&space, &tree, &q, 0.0, &params));
        // Query in the void: anomaly.
        let q = vec![500.0f32, 500.0];
        let qsq = 2.0 * 500.0f64 * 500.0;
        assert!(tree_is_anomaly_vec(&space, &tree, &q, qsq, &params));
    }
}
