//! X-means: K-means with automatic estimation of k (Pelleg & Moore 2000,
//! cited in the paper's references as the companion use of these trees).
//!
//! Algorithm: run (tree-accelerated, exact) K-means at the current k;
//! then for every centroid, split it in two, improve the pair *locally*
//! on the points it owns, and keep the split iff it improves the BIC
//! (Bayesian Information Criterion) of that local region under an
//! identical-spherical-Gaussian model. Repeat until no split survives or
//! `k_max` is reached.
//!
//! All heavy lifting reuses the metric tree: global passes via
//! [`kmeans::tree_lloyd`], local refinement via plain Lloyd over the
//! owned subsets (which are small).

use crate::algorithms::kmeans::{self, KmeansOpts};
use crate::metrics::{dense_dot, Space};
use crate::parallel::Executor;
use crate::rng::Rng;
use crate::tree::MetricTree;

/// Result of an X-means run.
#[derive(Clone, Debug)]
pub struct XmeansResult {
    pub centroids: Vec<Vec<f32>>,
    pub k: usize,
    pub distortion: f64,
    pub bic: f64,
    pub dists: u64,
    /// (k, bic) trajectory across improvement rounds.
    pub history: Vec<(usize, f64)>,
}

/// BIC of a spherical-Gaussian K-means model (Pelleg & Moore's formula).
/// `distortion` = Σ min‖x−μ‖², `n` points, `k` centers, `d` dims.
pub fn bic(distortion: f64, n: usize, k: usize, d: usize) -> f64 {
    if n <= k {
        return f64::NEG_INFINITY;
    }
    let n_f = n as f64;
    let d_f = d as f64;
    // MLE of the shared spherical variance.
    let var = (distortion / (d_f * (n_f - k as f64))).max(1e-12);
    // Log-likelihood of the clustered data.
    let loglik = -0.5 * n_f * d_f * (2.0 * std::f64::consts::PI * var).ln()
        - 0.5 * d_f * (n_f - k as f64)
        + n_f * (1.0 / k as f64).ln(); // uniform cluster priors
    let params = (k as f64) * (d_f + 1.0); // centers + shared variance per center
    loglik - 0.5 * params * n_f.ln()
}

/// Local distortion of `points` against a set of centers.
fn local_distortion(space: &Space, points: &[u32], centers: &[Vec<f32>]) -> f64 {
    // pallas-lint: allow(uncounted-dist, centroid norm staging for local distortion)
    let c_sq: Vec<f64> = centers.iter().map(|c| dense_dot(c, c)).collect();
    points
        .iter()
        .map(|&p| {
            centers
                .iter()
                .enumerate()
                .map(|(ci, c)| {
                    space.count_bulk(1);
                    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
                    space.dist_to_vec_uncounted(p as usize, c, c_sq[ci]).powi(2)
                })
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

/// A few Lloyd iterations restricted to `points` with 2 seeds.
fn local_2means(
    space: &Space,
    points: &[u32],
    seed_a: Vec<f32>,
    seed_b: Vec<f32>,
    iters: usize,
) -> (Vec<Vec<f32>>, f64) {
    let d = space.dim();
    let mut centers = vec![seed_a, seed_b];
    let mut dist = f64::INFINITY;
    for _ in 0..iters {
        // pallas-lint: allow(uncounted-dist, centroid norm staging per Lloyd iteration)
        let c_sq: Vec<f64> = centers.iter().map(|c| dense_dot(c, c)).collect();
        let mut sums = vec![vec![0f64; d]; 2];
        let mut counts = [0u64; 2];
        dist = 0.0;
        for &p in points {
            space.count_bulk(2);
            // pallas-lint: allow(uncounted-dist, counted via the count_bulk 2 above)
            let d0 = space.dist_to_vec_uncounted(p as usize, &centers[0], c_sq[0]);
            // pallas-lint: allow(uncounted-dist, counted via the count_bulk 2 above)
            let d1 = space.dist_to_vec_uncounted(p as usize, &centers[1], c_sq[1]);
            let (win, dd) = if d0 <= d1 { (0, d0) } else { (1, d1) };
            counts[win] += 1;
            space.accumulate(p as usize, &mut sums[win]);
            dist += dd * dd;
        }
        for c in 0..2 {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                for (j, v) in centers[c].iter_mut().enumerate() {
                    *v = (sums[c][j] * inv) as f32;
                }
            }
        }
    }
    (centers, dist)
}

/// Run X-means between `k_min` and `k_max` clusters. Builds a fresh
/// executor from [`KmeansOpts::parallelism`]; callers that hold a
/// long-lived pool (the engine facade) use [`xmeans_ex`].
pub fn xmeans(
    space: &Space,
    tree: &MetricTree,
    k_min: usize,
    k_max: usize,
    opts: &KmeansOpts,
) -> XmeansResult {
    xmeans_ex(space, tree, k_min, k_max, opts, &Executor::new(opts.parallelism))
}

/// [`xmeans`] on an explicit executor: the global improve-params passes
/// (via [`kmeans::tree_lloyd_ex`]) and the ownership pass all reuse one
/// persistent worker pool across every improvement round.
pub fn xmeans_ex(
    space: &Space,
    tree: &MetricTree,
    k_min: usize,
    k_max: usize,
    opts: &KmeansOpts,
    exec: &Executor,
) -> XmeansResult {
    assert!(k_min >= 1 && k_min <= k_max);
    let before = space.dist_count();
    let d = space.dim();
    let mut rng = Rng::new(opts.seed ^ 0x9E3779B9);
    let mut history = Vec::new();

    // Improve-params at k_min.
    let mut result =
        kmeans::tree_lloyd_ex(space, tree, kmeans::Init::Anchors, k_min, 10, opts, exec);
    let mut centroids = result.centroids.clone();
    history.push((centroids.len(), bic(result.distortion, space.n(), centroids.len(), d)));

    loop {
        if centroids.len() >= k_max {
            break;
        }
        // Ownership of each point (needed for local split tests).
        let labels = kmeans::assign_labels_ex(space, &centroids, exec);
        space.count_bulk((space.n() * centroids.len()) as u64);
        let mut owned: Vec<Vec<u32>> = vec![Vec::new(); centroids.len()];
        for (p, &l) in labels.iter().enumerate() {
            owned[l as usize].push(p as u32);
        }

        // Improve-structure: try splitting each centroid.
        let mut next_centroids: Vec<Vec<f32>> = Vec::new();
        let mut any_split = false;
        for (ci, center) in centroids.iter().enumerate() {
            let pts = &owned[ci];
            if pts.len() < 8 || centroids.len() + (next_centroids.len() - ci) >= k_max {
                next_centroids.push(center.clone());
                continue;
            }
            // Parent BIC on this region.
            let parent_dist = local_distortion(space, pts, std::slice::from_ref(center));
            let parent_bic = bic(parent_dist, pts.len(), 1, d);
            // Child seeds: center ± a random direction scaled to the
            // region's spread.
            let spread = (parent_dist / pts.len() as f64).sqrt().max(1e-6);
            let dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            let sa: Vec<f32> = center
                .iter()
                .zip(&dir)
                .map(|(&c, &v)| c + (v / norm * spread) as f32)
                .collect();
            let sb: Vec<f32> = center
                .iter()
                .zip(&dir)
                .map(|(&c, &v)| c - (v / norm * spread) as f32)
                .collect();
            let (children, child_dist) = local_2means(space, pts, sa, sb, 6);
            let child_bic = bic(child_dist, pts.len(), 2, d);
            if child_bic > parent_bic {
                next_centroids.push(children[0].clone());
                next_centroids.push(children[1].clone());
                any_split = true;
            } else {
                next_centroids.push(center.clone());
            }
        }
        if !any_split {
            break;
        }
        // Improve-params at the new k (global, tree-accelerated, exact).
        let k = next_centroids.len();
        result = kmeans::tree_lloyd_ex(
            space,
            tree,
            kmeans::Init::Given(next_centroids),
            k,
            8,
            opts,
            exec,
        );
        centroids = result.centroids.clone();
        history.push((k, bic(result.distortion, space.n(), k, d)));
    }

    let final_bic = bic(result.distortion, space.n(), centroids.len(), d);
    XmeansResult {
        k: centroids.len(),
        centroids,
        distortion: result.distortion,
        bic: final_bic,
        dists: space.dist_count() - before,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn blobs(k: usize, per: usize, sep: f64, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for c in 0..k {
            let cx = (c % 4) as f64 * sep;
            let cy = (c / 4) as f64 * sep;
            for _ in 0..per {
                rows.push(vec![(cx + rng.normal()) as f32, (cy + rng.normal()) as f32]);
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn recovers_true_k_on_separated_blobs() {
        for true_k in [3usize, 5] {
            let space = blobs(true_k, 120, 40.0, true_k as u64);
            let tree =
                middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
            let r = xmeans(&space, &tree, 1, 12, &KmeansOpts::default());
            assert_eq!(
                r.k, true_k,
                "expected k={true_k}, got {} (history {:?})",
                r.k, r.history
            );
        }
    }

    #[test]
    fn does_not_oversplit_single_gaussian() {
        let space = blobs(1, 400, 0.0, 9);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let r = xmeans(&space, &tree, 1, 8, &KmeansOpts::default());
        assert!(r.k <= 2, "split a single gaussian into {}", r.k);
    }

    #[test]
    fn respects_k_max() {
        let space = blobs(8, 60, 50.0, 11);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let r = xmeans(&space, &tree, 1, 4, &KmeansOpts::default());
        assert!(r.k <= 4);
    }

    #[test]
    fn bic_prefers_right_model() {
        // Distortion halves when k doubles appropriately → BIC should
        // reward genuine structure but penalize overfitting.
        let n = 1000;
        let d = 2;
        let good_fit = bic(500.0, n, 3, d);
        let overfit = bic(480.0, n, 30, d); // tiny gain, huge k
        assert!(good_fit > overfit);
        let underfit = bic(50_000.0, n, 1, d);
        assert!(good_fit > underfit);
    }

    #[test]
    fn history_is_monotone_in_k() {
        let space = blobs(4, 100, 40.0, 13);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let r = xmeans(&space, &tree, 1, 10, &KmeansOpts::default());
        for w in r.history.windows(2) {
            assert!(w[1].0 > w[0].0, "k must grow: {:?}", r.history);
        }
    }
}
