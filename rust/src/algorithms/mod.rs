pub mod kmeans;
pub mod anomaly;
pub mod allpairs;
pub mod knn;
pub mod mst;
pub mod gaussian;
pub mod ballquery;
pub mod xmeans;
