//! Bounded-error kernel density estimation and Nadaraya-Watson kernel
//! regression — the "statistical learning algorithms" half of the
//! paper's thesis, answered from cached sufficient statistics.
//!
//! For a query q and a monotone non-increasing kernel K, every tree node
//! at pivot distance d with radius r bounds its own kernel-sum
//! contribution by the triangle inequality:
//!
//! ```text
//!   count·K(d + r)  ≤  Σ_{x ∈ node} K(‖q − x‖)  ≤  count·K(max(0, d − r))
//! ```
//!
//! The traversal approximates a whole node by the interval midpoint
//! whenever the interval half-width fits the node's share of the error
//! budget, and recurses otherwise; only unresolved leaves touch raw
//! points (contiguous-arena blocked kernels, exact counts). The budget
//! is split *per point*: a node holding `count` of the `n` points may
//! spend `count/n` of the total allowance, so the pruned errors sum to
//! at most `eps_abs + eps_rel·S` (the relative term is charged against a
//! running **lower bound** `L ≤ S` of the true kernel sum, which only
//! ever grows — Gray & Moore's finite-difference pruning rule).
//!
//! Kernel regression rides the same traversal: the weight-sum
//! (denominator) error is bounded exactly as in KDE, and the
//! weighted-sum (numerator) error uses the per-dimension second moments
//! cached on every node ([`crate::tree::Node::sum2`]) via
//! Cauchy–Schwarz:
//!
//! ```text
//!   |Σ (K_i − K̄)·y_i|  ≤  (ΔK/2)·Σ|y_i|  ≤  (ΔK/2)·√(count·Σy_i²)
//! ```
//!
//! so approximating a node by `K̄·Σy` (cached `sum[t]`) is safe whenever
//! the same ΔK test that admits the KDE prune passes. The response `y`
//! is a designated coordinate of the dataset (`target_dim`); smoothing
//! weights use the full metric.
//!
//! Everything is deterministic: fixed DFS order (first child, then
//! second), ordered accumulation, and exact distance accounting
//! (`count_bulk(1)` per node bound, blocked kernels per leaf row).

use crate::metrics::{block, dense_dot, Space};
use crate::tree::{MetricTree, NodeId};

/// Smoothing kernels. All are non-increasing in the distance, `K(0) = 1`
/// — the only properties the pruning bounds rely on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// `K(d) = exp(−d² / 2h²)` — infinite support.
    Gaussian,
    /// `K(d) = max(0, 1 − (d/h)²)` — compact support: nodes entirely
    /// farther than `h` prune exactly, budget untouched.
    Epanechnikov,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Gaussian => "gaussian",
            Kernel::Epanechnikov => "epanechnikov",
        }
    }

    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "gaussian" => Some(Kernel::Gaussian),
            "epanechnikov" => Some(Kernel::Epanechnikov),
            _ => None,
        }
    }

    /// Evaluate `K(d)` at bandwidth `h` (`d ≥ 0`, `h > 0`).
    #[inline]
    pub fn eval(&self, d: f64, h: f64) -> f64 {
        let u = d / h;
        match self {
            Kernel::Gaussian => (-0.5 * u * u).exp(),
            Kernel::Epanechnikov => {
                if u >= 1.0 {
                    0.0
                } else {
                    1.0 - u * u
                }
            }
        }
    }
}

/// The user-supplied error budget on the kernel sum: the traversal
/// guarantees `|Ŝ − S| ≤ eps_abs + eps_rel·S`. `(0, 0)` forces an exact
/// evaluation (only zero-width node intervals prune).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorBudget {
    pub eps_abs: f64,
    pub eps_rel: f64,
}

/// Result of a (naive or tree-pruned) KDE evaluation at one query point.
#[derive(Clone, Debug, PartialEq)]
pub struct KdeResult {
    /// Estimated kernel sum `Ŝ = Σ K(‖q − x_i‖)` (un-normalized).
    pub sum: f64,
    /// `Ŝ / n` — the density estimate up to the kernel's normalizing
    /// constant (which depends only on `h` and `d`, not the data).
    pub density: f64,
    /// Accumulated worst-case `|Ŝ − S|`; 0 for the naive path.
    pub error_bound: f64,
    /// Nodes approximated wholesale (telemetry for tests/benches).
    pub whole_nodes: usize,
    /// Distance computations used.
    pub dists: u64,
}

/// Result of a (naive or tree-pruned) Nadaraya-Watson evaluation.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelRegressionResult {
    /// `ŷ(q) = N̂ / Ŵ` (0 when the weight sum vanishes).
    pub prediction: f64,
    /// Estimated weight sum `Ŵ = Σ K_i` (the KDE sum).
    pub weight_sum: f64,
    /// Estimated weighted response sum `N̂ = Σ K_i·y_i`.
    pub weighted_sum: f64,
    /// Accumulated worst-case `|Ŵ − W|`.
    pub weight_error_bound: f64,
    /// Worst-case `|ŷ − y|` implied by the numerator/denominator
    /// intervals (saturates at `f64::MAX` when the weight lower bound
    /// hits zero; never NaN/∞, per the wire contract).
    pub value_error_bound: f64,
    /// Nodes approximated wholesale.
    pub whole_nodes: usize,
    /// Distance computations used.
    pub dists: u64,
}

/// Naive O(n) KDE reference: exact kernel sum via the streamed blocked
/// scan (identical distances and counts to a pointwise loop).
pub fn naive_kde(space: &Space, center: &[f32], kernel: Kernel, h: f64) -> KdeResult {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; the scan distances are counted by the blocked kernel)
    let c_sq = dense_dot(center, center);
    let mut sum = 0.0f64;
    let mut dists: Vec<f64> = Vec::new();
    let mut lo = 0usize;
    while lo < space.n() {
        let hi = (lo + block::SCAN_CHUNK).min(space.n());
        space.checkpoint();
        space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
        block::dists_contig_to_vec(space, lo..hi, center, c_sq, &mut dists);
        for &d in &dists {
            sum += kernel.eval(d, h);
        }
        lo = hi;
    }
    let n = space.n();
    KdeResult {
        sum,
        density: if n == 0 { 0.0 } else { sum / n as f64 },
        error_bound: 0.0,
        whole_nodes: 0,
        dists: space.dist_count() - before,
    }
}

struct KdeAcc {
    sum: f64,
    err: f64,
    /// Running lower bound on the true kernel sum (exact leaf mass plus
    /// pruned nodes' `count·kmin`) — the base of the relative budget.
    lower: f64,
    whole_nodes: usize,
}

/// Tree-pruned KDE under the given error budget.
pub fn tree_kde(
    space: &Space,
    tree: &MetricTree,
    center: &[f32],
    kernel: Kernel,
    h: f64,
    budget: ErrorBudget,
) -> KdeResult {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; node distances counted in the recursion)
    let c_sq = dense_dot(center, center);
    let mut acc = KdeAcc { sum: 0.0, err: 0.0, lower: 0.0, whole_nodes: 0 };
    let n = tree.n_points();
    let mut dists: Vec<f64> = Vec::new();
    kde_recurse(
        space, tree, tree.root, center, c_sq, kernel, h, budget, n, 0, &mut acc, &mut dists,
    );
    KdeResult {
        sum: acc.sum,
        density: if n == 0 { 0.0 } else { acc.sum / n as f64 },
        error_bound: acc.err,
        whole_nodes: acc.whole_nodes,
        dists: space.dist_count() - before,
    }
}

/// Kernel bounds for one node: `(kmin, kmax)` of `K` over the node ball.
#[inline]
fn node_kernel_bounds(d: f64, radius: f64, kernel: Kernel, h: f64) -> (f64, f64) {
    let kmin = kernel.eval(d + radius, h);
    let kmax = kernel.eval((d - radius).max(0.0), h);
    (kmin, kmax)
}

#[allow(clippy::too_many_arguments)]
fn kde_recurse(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    center: &[f32],
    c_sq: f64,
    kernel: Kernel,
    h: f64,
    budget: ErrorBudget,
    n: usize,
    depth: usize,
    acc: &mut KdeAcc,
    dists: &mut Vec<f64>,
) {
    let node = tree.node(id);
    space.checkpoint();
    space.count_bulk(1);
    space.obs().visit(depth);
    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
    let d2 = (c_sq + node.pivot_sq - 2.0 * dense_dot(center, &node.pivot)).max(0.0);
    let d = d2.sqrt();
    let (kmin, kmax) = node_kernel_bounds(d, node.radius, kernel, h);
    let count = node.count as f64;
    // Per-point allowance, relative term charged against the running
    // lower bound (including this node's own guaranteed mass).
    let tol = (budget.eps_abs + budget.eps_rel * (acc.lower + count * kmin)) / n as f64;
    let half_width = (kmax - kmin) / 2.0;
    if half_width <= tol {
        acc.sum += count * (kmin + kmax) / 2.0;
        acc.err += count * half_width;
        acc.lower += count * kmin;
        acc.whole_nodes += 1;
        space.obs().prune(crate::obs::PruneRule::Budget);
        return;
    }
    match node.children {
        Some((a, b)) => {
            kde_recurse(space, tree, a, center, c_sq, kernel, h, budget, n, depth + 1, acc, dists);
            kde_recurse(space, tree, b, center, c_sq, kernel, h, budget, n, depth + 1, acc, dists);
        }
        None => {
            // Unresolved leaf: exact kernel sum over its contiguous
            // arena rows — one sequential slab, counted per tile.
            let arena = tree.arena();
            let rows = tree.node_rows(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
            block::dists_contig_to_vec(arena, rows, center, c_sq, dists);
            let mut exact = 0.0f64;
            for &d in dists.iter() {
                exact += kernel.eval(d, h);
            }
            acc.sum += exact;
            acc.lower += exact;
        }
    }
}

/// Naive O(n) Nadaraya-Watson reference: exact numerator and denominator
/// via the streamed blocked scan. The response is coordinate
/// `target_dim` of each datapoint.
pub fn naive_kernel_regression(
    space: &Space,
    center: &[f32],
    target_dim: usize,
    kernel: Kernel,
    h: f64,
) -> KernelRegressionResult {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; the scan distances are counted by the blocked kernel)
    let c_sq = dense_dot(center, center);
    let mut wsum = 0.0f64;
    let mut nsum = 0.0f64;
    let mut dists: Vec<f64> = Vec::new();
    let mut lo = 0usize;
    while lo < space.n() {
        let hi = (lo + block::SCAN_CHUNK).min(space.n());
        space.checkpoint();
        space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
        block::dists_contig_to_vec(space, lo..hi, center, c_sq, &mut dists);
        for (off, &d) in dists.iter().enumerate() {
            let k = kernel.eval(d, h);
            wsum += k;
            nsum += k * space.coord(lo + off, target_dim) as f64;
        }
        lo = hi;
    }
    KernelRegressionResult {
        prediction: if wsum > 0.0 { nsum / wsum } else { 0.0 },
        weight_sum: wsum,
        weighted_sum: nsum,
        weight_error_bound: 0.0,
        value_error_bound: 0.0,
        whole_nodes: 0,
        dists: space.dist_count() - before,
    }
}

struct KregAcc {
    wsum: f64,
    nsum: f64,
    werr: f64,
    nerr: f64,
    lower: f64,
    whole_nodes: usize,
}

/// Tree-pruned Nadaraya-Watson under the given weight-sum error budget.
pub fn tree_kernel_regression(
    space: &Space,
    tree: &MetricTree,
    center: &[f32],
    target_dim: usize,
    kernel: Kernel,
    h: f64,
    budget: ErrorBudget,
) -> KernelRegressionResult {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; node distances counted in the recursion)
    let c_sq = dense_dot(center, center);
    let mut acc = KregAcc {
        wsum: 0.0,
        nsum: 0.0,
        werr: 0.0,
        nerr: 0.0,
        lower: 0.0,
        whole_nodes: 0,
    };
    let n = tree.n_points();
    let mut dists: Vec<f64> = Vec::new();
    kreg_recurse(
        space, tree, tree.root, center, c_sq, target_dim, kernel, h, budget, n, 0, &mut acc,
        &mut dists,
    );
    let prediction = if acc.wsum > 0.0 { acc.nsum / acc.wsum } else { 0.0 };
    // |N/W − N̂/Ŵ| ≤ (nerr + |ŷ|·werr) / (W ≥ Ŵ − werr), when that lower
    // bound is positive; otherwise the interval is unbounded — saturate
    // to a finite sentinel so the wire layer stays NaN/∞-free.
    let w_lo = acc.wsum - acc.werr;
    let value_error_bound = if acc.werr == 0.0 && acc.nerr == 0.0 {
        0.0
    } else if w_lo > 0.0 {
        ((acc.nerr + prediction.abs() * acc.werr) / w_lo).min(f64::MAX)
    } else {
        f64::MAX
    };
    KernelRegressionResult {
        prediction,
        weight_sum: acc.wsum,
        weighted_sum: acc.nsum,
        weight_error_bound: acc.werr,
        value_error_bound,
        whole_nodes: acc.whole_nodes,
        dists: space.dist_count() - before,
    }
}

#[allow(clippy::too_many_arguments)]
fn kreg_recurse(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    center: &[f32],
    c_sq: f64,
    target_dim: usize,
    kernel: Kernel,
    h: f64,
    budget: ErrorBudget,
    n: usize,
    depth: usize,
    acc: &mut KregAcc,
    dists: &mut Vec<f64>,
) {
    let node = tree.node(id);
    space.checkpoint();
    space.count_bulk(1);
    space.obs().visit(depth);
    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
    let d2 = (c_sq + node.pivot_sq - 2.0 * dense_dot(center, &node.pivot)).max(0.0);
    let d = d2.sqrt();
    let (kmin, kmax) = node_kernel_bounds(d, node.radius, kernel, h);
    let count = node.count as f64;
    let tol = (budget.eps_abs + budget.eps_rel * (acc.lower + count * kmin)) / n as f64;
    let half_width = (kmax - kmin) / 2.0;
    if half_width <= tol {
        let mid = (kmin + kmax) / 2.0;
        acc.wsum += count * mid;
        acc.werr += count * half_width;
        // Numerator midpoint K̄·Σy from the cached first moment; its
        // error ≤ (ΔK/2)·√(count·Σy²) by Cauchy–Schwarz, from the
        // cached per-dimension second moment.
        acc.nsum += mid * node.sum[target_dim];
        acc.nerr += half_width * (count * node.sum2[target_dim]).sqrt();
        acc.lower += count * kmin;
        acc.whole_nodes += 1;
        space.obs().prune(crate::obs::PruneRule::Budget);
        return;
    }
    match node.children {
        Some((a, b)) => {
            kreg_recurse(
                space, tree, a, center, c_sq, target_dim, kernel, h, budget, n, depth + 1, acc,
                dists,
            );
            kreg_recurse(
                space, tree, b, center, c_sq, target_dim, kernel, h, budget, n, depth + 1, acc,
                dists,
            );
        }
        None => {
            let arena = tree.arena();
            let rows = tree.node_rows(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
            block::dists_contig_to_vec(arena, rows.clone(), center, c_sq, dists);
            let mut w_exact = 0.0f64;
            for (r, &d) in rows.zip(dists.iter()) {
                let k = kernel.eval(d, h);
                w_exact += k;
                acc.nsum += k * arena.coord(r, target_dim) as f64;
            }
            acc.wsum += w_exact;
            acc.lower += w_exact;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn clustered(seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for c in 0..5 {
            for _ in 0..100 {
                rows.push(vec![
                    (c as f64 * 25.0 + rng.normal() * 2.0) as f32,
                    (rng.normal() * 2.0) as f32,
                    ((c % 2) as f64 * 10.0 + rng.normal()) as f32,
                ]);
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn kernel_shapes() {
        for k in [Kernel::Gaussian, Kernel::Epanechnikov] {
            assert_eq!(k.eval(0.0, 2.0), 1.0);
            // Non-increasing in d.
            let mut prev = 1.0;
            for i in 1..40 {
                let v = k.eval(i as f64 * 0.25, 2.0);
                assert!(v <= prev + 1e-15, "{:?} not monotone at {i}", k);
                assert!(v >= 0.0);
                prev = v;
            }
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert_eq!(Kernel::Epanechnikov.eval(2.0, 2.0), 0.0);
        assert_eq!(Kernel::parse("triweight"), None);
    }

    #[test]
    fn tree_kde_within_budget_of_naive() {
        let space = clustered(1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        for kernel in [Kernel::Gaussian, Kernel::Epanechnikov] {
            for h in [1.0, 5.0, 30.0] {
                for (eps_abs, eps_rel) in [(0.5, 0.0), (0.0, 0.01), (1.0, 0.05)] {
                    let budget = ErrorBudget { eps_abs, eps_rel };
                    let center = vec![25.0f32, 0.0, 5.0];
                    let naive = naive_kde(&space, &center, kernel, h);
                    let fast = tree_kde(&space, &tree, &center, kernel, h, budget);
                    let allowed = eps_abs + eps_rel * naive.sum + 1e-9;
                    assert!(
                        (fast.sum - naive.sum).abs() <= allowed,
                        "{kernel:?} h={h} budget=({eps_abs},{eps_rel}): {} vs {} (allowed {allowed})",
                        fast.sum,
                        naive.sum
                    );
                    // The reported bound is itself honest.
                    assert!((fast.sum - naive.sum).abs() <= fast.error_bound + 1e-9);
                    assert!(fast.error_bound <= allowed);
                }
            }
        }
    }

    #[test]
    fn zero_budget_is_exact() {
        let space = clustered(2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let center = vec![0.0f32, 0.0, 0.0];
        let budget = ErrorBudget { eps_abs: 0.0, eps_rel: 0.0 };
        let naive = naive_kde(&space, &center, Kernel::Gaussian, 3.0);
        let fast = tree_kde(&space, &tree, &center, Kernel::Gaussian, 3.0, budget);
        // With no budget every Gaussian node descends to leaves; leaf
        // kernels are the same blocked scan in the same row order.
        assert!((fast.sum - naive.sum).abs() < 1e-9 * (1.0 + naive.sum));
        assert_eq!(fast.error_bound, 0.0);
        // Compactly supported kernels still prune exactly at zero budget.
        let e = tree_kde(&space, &tree, &center, Kernel::Epanechnikov, 3.0, budget);
        let en = naive_kde(&space, &center, Kernel::Epanechnikov, 3.0);
        assert!((e.sum - en.sum).abs() < 1e-9 * (1.0 + en.sum));
        assert!(e.dists < space.n() as u64, "compact support never pruned");
    }

    #[test]
    fn budget_buys_pruning() {
        let space = clustered(3);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let center = vec![25.0f32, 0.0, 5.0];
        let tight = tree_kde(
            &space, &tree, &center, Kernel::Gaussian, 2.0,
            ErrorBudget { eps_abs: 0.0, eps_rel: 0.0 },
        );
        let loose = tree_kde(
            &space, &tree, &center, Kernel::Gaussian, 2.0,
            ErrorBudget { eps_abs: 1.0, eps_rel: 0.05 },
        );
        assert!(
            loose.dists < tight.dists,
            "budget did not reduce work: {} vs {}",
            loose.dists,
            tight.dists
        );
        assert!(loose.whole_nodes > 0);
    }

    #[test]
    fn tree_kreg_within_bounds_of_naive() {
        let space = clustered(4);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let center = vec![50.0f32, 0.0, 0.0];
        for (eps_abs, eps_rel) in [(0.0, 0.0), (0.5, 0.0), (0.2, 0.02)] {
            let budget = ErrorBudget { eps_abs, eps_rel };
            let naive = naive_kernel_regression(&space, &center, 2, Kernel::Gaussian, 8.0);
            let fast =
                tree_kernel_regression(&space, &tree, &center, 2, Kernel::Gaussian, 8.0, budget);
            assert!(
                (fast.weight_sum - naive.weight_sum).abs() <= fast.weight_error_bound + 1e-9,
                "weight sum {} vs {} exceeds bound {}",
                fast.weight_sum,
                naive.weight_sum,
                fast.weight_error_bound
            );
            assert!(
                (fast.prediction - naive.prediction).abs() <= fast.value_error_bound + 1e-9,
                "prediction {} vs {} exceeds bound {}",
                fast.prediction,
                naive.prediction,
                fast.value_error_bound
            );
            assert!(fast.value_error_bound.is_finite());
        }
    }

    #[test]
    fn empty_weight_sum_predicts_zero() {
        let space = clustered(5);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        // Epanechnikov far from all mass: every kernel value is exactly 0.
        let center = vec![5000.0f32, 5000.0, 5000.0];
        let r = tree_kernel_regression(
            &space, &tree, &center, 0, Kernel::Epanechnikov, 1.0,
            ErrorBudget { eps_abs: 0.0, eps_rel: 0.0 },
        );
        assert_eq!(r.prediction, 0.0);
        assert_eq!(r.weight_sum, 0.0);
        assert!(r.value_error_bound.is_finite());
    }

    #[test]
    fn deterministic_across_runs() {
        let space = clustered(6);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let center = vec![12.0f32, 1.0, 3.0];
        let budget = ErrorBudget { eps_abs: 0.3, eps_rel: 0.01 };
        let run = || {
            let before = space.dist_count();
            let k = tree_kde(&space, &tree, &center, Kernel::Gaussian, 4.0, budget);
            let r = tree_kernel_regression(
                &space, &tree, &center, 1, Kernel::Gaussian, 4.0, budget,
            );
            (k, r, space.dist_count() - before)
        };
        let (k1, r1, d1) = run();
        let (k2, r2, d2) = run();
        assert_eq!(k1, k2);
        assert_eq!(r1, r2);
        assert_eq!(d1, d2);
    }
}
