//! Euclidean minimum spanning trees / dependency trees (paper §6).
//!
//! The paper's future-work list includes dependency-tree learning by
//! running a spanning-tree algorithm in attribute space (maximum
//! correlation = minimum distance after standardization, eq. 8). We
//! implement Borůvka's algorithm with tree-accelerated
//! "nearest-foreign-neighbor" queries: each round, every component finds
//! its closest outside point using the metric tree, pruning subtrees that
//! (a) lie entirely inside the component or (b) are provably farther than
//! the component's current best candidate.

use crate::metrics::Space;
use crate::tree::{MetricTree, NodeId};

/// An MST edge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub a: u32,
    pub b: u32,
    pub dist: f64,
}

/// Union–find with path halving.
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect() }
    }
    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }
    fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        self.parent[ra as usize] = rb;
        true
    }
}

/// Naive Prim's algorithm — O(R²) counted distances. The oracle baseline.
pub fn naive_mst(space: &Space) -> Vec<Edge> {
    let n = space.n();
    if n <= 1 {
        return Vec::new();
    }
    let mut in_tree = vec![false; n];
    let mut best_d = vec![f64::INFINITY; n];
    let mut best_from = vec![0u32; n];
    let mut edges = Vec::with_capacity(n - 1);
    in_tree[0] = true;
    space.obs().leaf_rows(crate::ids::u64_from_usize(n - 1));
    for j in 1..n {
        best_d[j] = space.dist(0, j);
        best_from[j] = 0;
    }
    for _ in 1..n {
        space.checkpoint();
        // Closest outside point.
        let (mut pick, mut pick_d) = (usize::MAX, f64::INFINITY);
        for j in 0..n {
            if !in_tree[j] && best_d[j] < pick_d {
                pick = j;
                pick_d = best_d[j];
            }
        }
        in_tree[pick] = true;
        edges.push(Edge { a: best_from[pick], b: pick as u32, dist: pick_d });
        let mut scanned = 0u64;
        for j in 0..n {
            if !in_tree[j] {
                scanned += 1;
                let d = space.dist(pick, j);
                if d < best_d[j] {
                    best_d[j] = d;
                    best_from[j] = pick as u32;
                }
            }
        }
        space.obs().leaf_rows(scanned);
    }
    edges
}

/// Borůvka's algorithm with metric-tree nearest-foreign-neighbor queries.
pub fn tree_mst(space: &Space, tree: &MetricTree) -> Vec<Edge> {
    let n = space.n();
    if n <= 1 {
        return Vec::new();
    }
    let mut uf = UnionFind::new(n);
    let mut edges: Vec<Edge> = Vec::with_capacity(n - 1);
    let mut n_components = n;

    // Reusable scratch.
    let mut qrow = vec![0f32; space.dim()];

    while n_components > 1 {
        // Per-node "all my points share this component" marker for the
        // current round (u32::MAX = mixed).
        let node_comp = compute_node_components(space, tree, &mut uf);

        // Best outgoing edge per component root. BTreeMap, not HashMap:
        // the merge loop below iterates this map, and hash iteration
        // order would make edge orientation and union order (hence
        // later-round distance counts) vary run to run.
        let mut best: std::collections::BTreeMap<u32, Edge> = std::collections::BTreeMap::new();
        for p in 0..n {
            let comp = uf.find(p as u32);
            space.fill_row(p, &mut qrow);
            let q_sq = space.data.sqnorm(p);
            let bound = best.get(&comp).map(|e| e.dist).unwrap_or(f64::INFINITY);
            if let Some((q, d)) =
                nearest_foreign(space, tree, &node_comp, &mut uf, comp, &qrow, q_sq, p as u32, bound)
            {
                let e = Edge { a: p as u32, b: q, dist: d };
                best
                    .entry(comp)
                    .and_modify(|cur| {
                        if e.dist < cur.dist {
                            *cur = e;
                        }
                    })
                    .or_insert(e);
            }
        }
        // Merge. (Classic Borůvka: each selected edge joins two components;
        // duplicates across components collapse via union-find.)
        let mut progressed = false;
        for (_, e) in best {
            if uf.union(e.a, e.b) {
                edges.push(e);
                n_components -= 1;
                progressed = true;
            }
        }
        assert!(progressed, "Borůvka round made no progress");
    }
    edges.sort_by(|x, y| x.dist.partial_cmp(&y.dist).unwrap());
    edges
}

/// DFS labelling: the component id if every point under the node agrees,
/// else u32::MAX.
fn compute_node_components(space: &Space, tree: &MetricTree, uf: &mut UnionFind) -> Vec<u32> {
    let _ = space;
    let mut marks = vec![u32::MAX; tree.nodes.len()];
    // Process in arena order; children always precede parents in both
    // builders (nodes are pushed bottom-up), so one forward pass works.
    for id in 0..tree.nodes.len() {
        let node = &tree.nodes[id];
        marks[id] = match node.children {
            None => {
                let mut comp = None;
                let mut same = true;
                for &p in tree.points_under(id as NodeId) {
                    let c = uf.find(p);
                    match comp {
                        None => comp = Some(c),
                        Some(cc) if cc != c => {
                            same = false;
                            break;
                        }
                        _ => {}
                    }
                }
                if same {
                    comp.unwrap_or(u32::MAX)
                } else {
                    u32::MAX
                }
            }
            Some((a, b)) => {
                let (ma, mb) = (marks[a as usize], marks[b as usize]);
                if ma == mb {
                    ma
                } else {
                    u32::MAX
                }
            }
        };
    }
    marks
}

/// Nearest point to `qrow` whose component differs from `comp`.
/// `bound` seeds the pruning radius with the component's current best.
#[allow(clippy::too_many_arguments)]
fn nearest_foreign(
    space: &Space,
    tree: &MetricTree,
    node_comp: &[u32],
    uf: &mut UnionFind,
    comp: u32,
    qrow: &[f32],
    q_sq: f64,
    skip: u32,
    bound: f64,
) -> Option<(u32, f64)> {
    let mut best: Option<(u32, f64)> = None;
    let mut best_d = bound;
    descend(
        space, tree, tree.root, node_comp, uf, comp, qrow, q_sq, skip, 0, &mut best, &mut best_d,
    );
    best
}

#[allow(clippy::too_many_arguments)]
fn descend(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    node_comp: &[u32],
    uf: &mut UnionFind,
    comp: u32,
    qrow: &[f32],
    q_sq: f64,
    skip: u32,
    depth: usize,
    best: &mut Option<(u32, f64)>,
    best_d: &mut f64,
) {
    // Prune: subtree entirely within our own component. (An identity
    // cut, not a geometric bound — deliberately not counted as a prune.)
    if node_comp[id as usize] == comp {
        return;
    }
    let node = tree.node(id);
    space.checkpoint();
    space.obs().visit(depth);
    // Prune: ball lower bound beats current best.
    space.count_bulk(1);
    let d_pivot = {
        use crate::metrics::{dense_dot, dense_l1, Metric};
        match space.metric {
            Metric::Euclidean => {
                // pallas-lint: allow(uncounted-dist, counted via count_bulk(1) above)
                let d2 = q_sq + node.pivot_sq - 2.0 * dense_dot(qrow, &node.pivot);
                d2.max(0.0).sqrt()
            }
            // pallas-lint: allow(uncounted-dist, counted via count_bulk(1) above)
            Metric::L1 => dense_l1(qrow, &node.pivot),
        }
    };
    if d_pivot - node.radius >= *best_d {
        space.obs().prune(crate::obs::PruneRule::Triangle);
        return;
    }
    match node.children {
        None => {
            // Leaf scan over the tree-order arena: rows stream
            // sequentially, ids come from the matching layout slice.
            // Stays pointwise (not a kernel) because the component
            // filter skips rows — computing their distances anyway
            // would inflate the count the paper measures.
            let arena = tree.arena();
            let ids = tree.points_under(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(ids.len()));
            for (r, &p) in tree.node_rows(id).zip(ids.iter()) {
                if p == skip || uf.find(p) == comp {
                    continue;
                }
                let d = arena.dist_to_vec(r, qrow, q_sq);
                if d < *best_d {
                    *best_d = d;
                    *best = Some((p, d));
                }
            }
        }
        Some((a, b)) => {
            // Closer child first. The comparisons are a traversal-order
            // heuristic only: they never reach results, and each child
            // pays its own counted pivot distance on entry.
            let (na, nb) = (tree.node(a), tree.node(b));
            // pallas-lint: allow(uncounted-dist, prune-order heuristic; children count on entry)
            let da = crate::metrics::dense_sqdist(qrow, &na.pivot);
            // pallas-lint: allow(uncounted-dist, prune-order heuristic; children count on entry)
            let db = crate::metrics::dense_sqdist(qrow, &nb.pivot);
            let (first, second) = if da <= db { (a, b) } else { (b, a) };
            descend(space, tree, first, node_comp, uf, comp, qrow, q_sq, skip, depth + 1, best, best_d);
            descend(space, tree, second, node_comp, uf, comp, qrow, q_sq, skip, depth + 1, best, best_d);
        }
    }
}

/// Total weight of an edge list.
pub fn total_weight(edges: &[Edge]) -> f64 {
    edges.iter().map(|e| e.dist).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn random_space(n: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let vals: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 10.0).collect();
        Space::euclidean(Data::Dense(DenseMatrix::new(n, d, vals)))
    }

    #[test]
    fn tree_mst_weight_matches_prim() {
        // MSTs may differ under ties but total weight is unique-ish for
        // generic (random continuous) data.
        for seed in [1u64, 2, 3] {
            let space = random_space(120, 2, seed);
            let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 8, ..Default::default() });
            let a = naive_mst(&space);
            let b = tree_mst(&space, &tree);
            assert_eq!(a.len(), 119);
            assert_eq!(b.len(), 119);
            let (wa, wb) = (total_weight(&a), total_weight(&b));
            assert!(
                (wa - wb).abs() < 1e-6 * (1.0 + wa),
                "seed {seed}: weights {wa} vs {wb}"
            );
        }
    }

    #[test]
    fn mst_is_spanning() {
        let space = random_space(80, 3, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let edges = tree_mst(&space, &tree);
        let mut uf = UnionFind::new(80);
        for e in &edges {
            uf.union(e.a, e.b);
        }
        let root = uf.find(0);
        for i in 1..80 {
            assert_eq!(uf.find(i), root, "point {i} disconnected");
        }
    }

    #[test]
    fn two_blobs_bridge_once() {
        // MST of two tight blobs must contain exactly one long bridge edge.
        let mut rng = Rng::new(5);
        let mut rows = Vec::new();
        for _ in 0..40 {
            rows.push(vec![rng.normal() as f32, rng.normal() as f32]);
        }
        for _ in 0..40 {
            rows.push(vec![(100.0 + rng.normal()) as f32, rng.normal() as f32]);
        }
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 8, ..Default::default() });
        let edges = tree_mst(&space, &tree);
        let long: Vec<&Edge> = edges.iter().filter(|e| e.dist > 50.0).collect();
        assert_eq!(long.len(), 1, "expected exactly one bridge: {long:?}");
    }

    #[test]
    fn trivial_sizes() {
        let space = random_space(1, 2, 6);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        assert!(tree_mst(&space, &tree).is_empty());
        let space = random_space(2, 2, 7);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let e = tree_mst(&space, &tree);
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn tree_mst_is_deterministic_across_runs() {
        // Regression for the Borůvka merge map: with a HashMap, per-round
        // merge order (hence edge orientation and later-round distance
        // counts) varied run to run. Repeated runs must now be
        // bit-identical, edges and accounting both.
        let space = random_space(150, 3, 9);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 8, ..Default::default() });
        space.reset_count();
        let first = tree_mst(&space, &tree);
        let first_dists = space.dist_count();
        for _ in 0..2 {
            space.reset_count();
            let again = tree_mst(&space, &tree);
            assert_eq!(space.dist_count(), first_dists, "distance count drifted");
            assert_eq!(again.len(), first.len());
            for (x, y) in first.iter().zip(&again) {
                assert_eq!((x.a, x.b), (y.a, y.b), "edge orientation drifted");
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
        }
    }

    #[test]
    fn tree_mst_saves_distances_on_clustered_data() {
        // The advantage grows with R (naive is Θ(R²), the dual pruning is
        // ~R·polylog per Borůvka round), so test at a size where the gap
        // is decisive.
        let mut rng = Rng::new(8);
        let mut rows = Vec::new();
        for c in 0..8 {
            for _ in 0..100 {
                rows.push(vec![
                    ((c % 4) as f64 * 100.0 + rng.normal()) as f32,
                    ((c / 4) as f64 * 100.0 + rng.normal()) as f32,
                ]);
            }
        }
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 10, ..Default::default() });
        space.reset_count();
        let _ = tree_mst(&space, &tree);
        let tree_d = space.dist_count();
        space.reset_count();
        let _ = naive_mst(&space);
        let naive_d = space.dist_count();
        assert!(
            tree_d * 2 < naive_d,
            "tree {tree_d} vs naive {naive_d} distances"
        );
    }
}
