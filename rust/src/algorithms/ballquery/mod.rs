//! Region statistics queries — the paper's §1 motivation made concrete.
//!
//! "Human users, or statistical programs, often need to query some
//! quantity (such as a mean or variance) over some subset of the records
//! … we want the cached sufficient statistic representation to intercept
//! the request and answer it immediately."
//!
//! This module answers **ball queries** — count / mean / per-dimension
//! variance of all points within radius `r` of a query center — exactly,
//! by recursing over the tree and consuming whole nodes' cached
//! statistics whenever the node ball lies entirely inside (or outside)
//! the query ball. Only boundary leaves touch raw points.
//!
//! Two flavors are exposed. [`tree_ball_stats`] consumes the scalar
//! second moment Σ‖x‖² cached per node and reports the *total* variance
//! (trace of the covariance) — what the distortion-style consumers
//! need. [`tree_ball_moments`] additionally consumes the per-dimension
//! second moments Σxᵢ² ([`crate::tree::Node::sum2`], snapshot format
//! `AHTREE03`) and reports the full per-dimension variance vector,
//! still exactly and still from cached statistics for every node wholly
//! inside the ball.

use crate::metrics::{block, dense_dot, Space};
use crate::tree::{MetricTree, NodeId};

/// Exact statistics of the points inside a query ball.
#[derive(Clone, Debug, PartialEq)]
pub struct BallStats {
    pub count: u64,
    /// Mean of the in-ball points (empty ball ⇒ zeros).
    pub mean: Vec<f32>,
    /// Total variance: (1/n)Σ‖x − mean‖² (trace of covariance).
    pub total_variance: f64,
    /// Distance computations used.
    pub dists: u64,
}

/// Accumulator for the recursion.
struct Acc {
    count: u64,
    sum: Vec<f64>,
    sumsq: f64,
    /// Nodes consumed wholesale (telemetry for tests/benches).
    whole_nodes: usize,
}

/// Naive baseline: scan all points (R counted distances).
///
/// With the f32 filter tier on, the threshold is the fixed query
/// radius: rows pruned by the f32 pre-pass provably satisfy
/// `d > radius`, which the tier-off membership test would also reject,
/// and survivors carry the exact f64 distance — so the accumulated
/// membership set, order and sums are bit-identical either way.
pub fn naive_ball_stats(space: &Space, center: &[f32], radius: f64) -> BallStats {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; the scan distances are counted by the blocked kernel)
    let c_sq = dense_dot(center, center);
    let mut acc = Acc {
        count: 0,
        sum: vec![0.0; space.dim()],
        sumsq: 0.0,
        whole_nodes: 0,
    };
    // Streamed through the blocked kernel in fixed chunks (O(chunk)
    // extra memory, identical distances and counts to the pointwise scan).
    let filter = block::F32Filter::new(space, center);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    let mut lo = 0usize;
    while lo < space.n() {
        let hi = (lo + block::SCAN_CHUNK).min(space.n());
        space.checkpoint();
        space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
        match &filter {
            Some(f) => {
                block::dists_contig_to_vec_f32(
                    space, lo..hi, center, c_sq, f, radius, &mut frows, &mut dists,
                );
                space.obs().prune_n(
                    crate::obs::PruneRule::F32Reject,
                    crate::ids::u64_from_usize(hi - lo - frows.len()),
                );
                for (&row, &d) in frows.iter().zip(&dists) {
                    if d <= radius {
                        let p = row as usize;
                        acc.count += 1;
                        space.accumulate(p, &mut acc.sum);
                        acc.sumsq += space.data.sqnorm(p);
                    }
                }
            }
            None => {
                block::dists_contig_to_vec(space, lo..hi, center, c_sq, &mut dists);
                for (off, &d) in dists.iter().enumerate() {
                    if d <= radius {
                        let p = lo + off;
                        acc.count += 1;
                        space.accumulate(p, &mut acc.sum);
                        acc.sumsq += space.data.sqnorm(p);
                    }
                }
            }
        }
        lo = hi;
    }
    finish(acc, space.dist_count() - before)
}

/// Tree-accelerated exact ball statistics.
pub fn tree_ball_stats(
    space: &Space,
    tree: &MetricTree,
    center: &[f32],
    radius: f64,
) -> BallStats {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; node distances counted in recurse)
    let c_sq = dense_dot(center, center);
    let mut acc = Acc {
        count: 0,
        sum: vec![0.0; space.dim()],
        sumsq: 0.0,
        whole_nodes: 0,
    };
    // Leaf-scan scratch, reused across every boundary leaf of the query.
    // The f32 filter (if the tier is on) is built on the arena the leaf
    // scans read; see `naive_ball_stats` for the exactness argument.
    let filter = block::F32Filter::new(tree.arena(), center);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    recurse(
        space, tree, tree.root, center, c_sq, radius, 0, &mut acc, &filter, &mut dists,
        &mut frows,
    );
    finish(acc, space.dist_count() - before)
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    center: &[f32],
    c_sq: f64,
    radius: f64,
    depth: usize,
    acc: &mut Acc,
    filter: &Option<block::F32Filter>,
    dists: &mut Vec<f64>,
    frows: &mut Vec<u32>,
) {
    let node = tree.node(id);
    space.checkpoint();
    space.count_bulk(1);
    space.obs().visit(depth);
    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
    let d2 = (c_sq + node.pivot_sq - 2.0 * dense_dot(center, &node.pivot)).max(0.0);
    let d = d2.sqrt();
    // Node entirely inside the query ball: consume cached statistics.
    // Both whole-in and whole-out settle the node from one pivot
    // distance — each is a triangle-inequality prune of the subtree.
    if d + node.radius <= radius {
        acc.count += node.count as u64;
        for (a, s) in acc.sum.iter_mut().zip(&node.sum) {
            *a += s;
        }
        acc.sumsq += node.sumsq;
        acc.whole_nodes += 1;
        space.obs().prune(crate::obs::PruneRule::Triangle);
        return;
    }
    // Node entirely outside: nothing.
    if d - node.radius > radius {
        space.obs().prune(crate::obs::PruneRule::Triangle);
        return;
    }
    match node.children {
        Some((a, b)) => {
            recurse(space, tree, a, center, c_sq, radius, depth + 1, acc, filter, dists, frows);
            recurse(space, tree, b, center, c_sq, radius, depth + 1, acc, filter, dists, frows);
        }
        None => {
            // Boundary leaf: contiguous kernel over the leaf's arena
            // rows — one sequential slab, bit-identical distances and
            // the same count as the gather scan it replaces. In-ball
            // rows accumulate straight from the arena (each arena row
            // is a bit-exact copy of its dataset row, so the sums match
            // the gather path add for add).
            let arena = tree.arena();
            let rows = tree.node_rows(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
            match filter {
                Some(f) => {
                    let n_rows = rows.len();
                    block::dists_contig_to_vec_f32(
                        arena, rows, center, c_sq, f, radius, frows, dists,
                    );
                    space.obs().prune_n(
                        crate::obs::PruneRule::F32Reject,
                        crate::ids::u64_from_usize(n_rows - frows.len()),
                    );
                    for (&row, &d) in frows.iter().zip(dists.iter()) {
                        if d <= radius {
                            let r = row as usize;
                            acc.count += 1;
                            arena.accumulate(r, &mut acc.sum);
                            acc.sumsq += arena.data.sqnorm(r);
                        }
                    }
                }
                None => {
                    block::dists_contig_to_vec(arena, rows.clone(), center, c_sq, dists);
                    for (r, &d) in rows.zip(dists.iter()) {
                        if d <= radius {
                            acc.count += 1;
                            arena.accumulate(r, &mut acc.sum);
                            acc.sumsq += arena.data.sqnorm(r);
                        }
                    }
                }
            }
        }
    }
}

/// Exact per-dimension statistics of the points inside a query ball —
/// the [`BallStats`] report extended with the full variance diagonal,
/// powered by the per-dimension second moments cached on every node.
#[derive(Clone, Debug, PartialEq)]
pub struct BallMoments {
    pub count: u64,
    /// Mean of the in-ball points (empty ball ⇒ zeros).
    pub mean: Vec<f32>,
    /// Per-dimension (biased, /n) variance of the in-ball points.
    pub variance: Vec<f64>,
    /// Total variance: trace of the covariance (= Σ variance\[i\]).
    pub total_variance: f64,
    /// Distance computations used.
    pub dists: u64,
}

/// Accumulator for the moments recursion.
struct MomentsAcc {
    count: u64,
    sum: Vec<f64>,
    sum2: Vec<f64>,
    sumsq: f64,
    whole_nodes: usize,
}

/// Naive baseline for [`tree_ball_moments`]: scan all points.
pub fn naive_ball_moments(space: &Space, center: &[f32], radius: f64) -> BallMoments {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; the scan distances are counted by the blocked kernel)
    let c_sq = dense_dot(center, center);
    let mut acc = MomentsAcc {
        count: 0,
        sum: vec![0.0; space.dim()],
        sum2: vec![0.0; space.dim()],
        sumsq: 0.0,
        whole_nodes: 0,
    };
    let filter = block::F32Filter::new(space, center);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    let mut lo = 0usize;
    while lo < space.n() {
        let hi = (lo + block::SCAN_CHUNK).min(space.n());
        space.checkpoint();
        space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
        match &filter {
            Some(f) => {
                block::dists_contig_to_vec_f32(
                    space, lo..hi, center, c_sq, f, radius, &mut frows, &mut dists,
                );
                space.obs().prune_n(
                    crate::obs::PruneRule::F32Reject,
                    crate::ids::u64_from_usize(hi - lo - frows.len()),
                );
                for (&row, &d) in frows.iter().zip(&dists) {
                    if d <= radius {
                        let p = row as usize;
                        acc.count += 1;
                        space.accumulate(p, &mut acc.sum);
                        space.accumulate_sq(p, &mut acc.sum2);
                        acc.sumsq += space.data.sqnorm(p);
                    }
                }
            }
            None => {
                block::dists_contig_to_vec(space, lo..hi, center, c_sq, &mut dists);
                for (off, &d) in dists.iter().enumerate() {
                    if d <= radius {
                        let p = lo + off;
                        acc.count += 1;
                        space.accumulate(p, &mut acc.sum);
                        space.accumulate_sq(p, &mut acc.sum2);
                        acc.sumsq += space.data.sqnorm(p);
                    }
                }
            }
        }
        lo = hi;
    }
    finish_moments(acc, space.dist_count() - before)
}

/// Tree-accelerated exact per-dimension ball statistics: whole-inside
/// nodes contribute their cached `sum`/`sum2`/`sumsq`, boundary leaves
/// run the contiguous-arena kernel.
pub fn tree_ball_moments(
    space: &Space,
    tree: &MetricTree,
    center: &[f32],
    radius: f64,
) -> BallMoments {
    let before = space.dist_count();
    // pallas-lint: allow(uncounted-dist, query norm staging; node distances counted in recurse)
    let c_sq = dense_dot(center, center);
    let mut acc = MomentsAcc {
        count: 0,
        sum: vec![0.0; space.dim()],
        sum2: vec![0.0; space.dim()],
        sumsq: 0.0,
        whole_nodes: 0,
    };
    let filter = block::F32Filter::new(tree.arena(), center);
    let mut dists: Vec<f64> = Vec::new();
    let mut frows: Vec<u32> = Vec::new();
    moments_recurse(
        space, tree, tree.root, center, c_sq, radius, 0, &mut acc, &filter, &mut dists,
        &mut frows,
    );
    finish_moments(acc, space.dist_count() - before)
}

#[allow(clippy::too_many_arguments)]
fn moments_recurse(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    center: &[f32],
    c_sq: f64,
    radius: f64,
    depth: usize,
    acc: &mut MomentsAcc,
    filter: &Option<block::F32Filter>,
    dists: &mut Vec<f64>,
    frows: &mut Vec<u32>,
) {
    let node = tree.node(id);
    space.checkpoint();
    space.count_bulk(1);
    space.obs().visit(depth);
    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
    let d2 = (c_sq + node.pivot_sq - 2.0 * dense_dot(center, &node.pivot)).max(0.0);
    let d = d2.sqrt();
    if d + node.radius <= radius {
        acc.count += node.count as u64;
        for (a, s) in acc.sum.iter_mut().zip(&node.sum) {
            *a += s;
        }
        for (a, s) in acc.sum2.iter_mut().zip(&node.sum2) {
            *a += s;
        }
        acc.sumsq += node.sumsq;
        acc.whole_nodes += 1;
        space.obs().prune(crate::obs::PruneRule::Triangle);
        return;
    }
    if d - node.radius > radius {
        space.obs().prune(crate::obs::PruneRule::Triangle);
        return;
    }
    match node.children {
        Some((a, b)) => {
            moments_recurse(
                space, tree, a, center, c_sq, radius, depth + 1, acc, filter, dists, frows,
            );
            moments_recurse(
                space, tree, b, center, c_sq, radius, depth + 1, acc, filter, dists, frows,
            );
        }
        None => {
            let arena = tree.arena();
            let rows = tree.node_rows(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
            match filter {
                Some(f) => {
                    let n_rows = rows.len();
                    block::dists_contig_to_vec_f32(
                        arena, rows, center, c_sq, f, radius, frows, dists,
                    );
                    space.obs().prune_n(
                        crate::obs::PruneRule::F32Reject,
                        crate::ids::u64_from_usize(n_rows - frows.len()),
                    );
                    for (&row, &d) in frows.iter().zip(dists.iter()) {
                        if d <= radius {
                            let r = row as usize;
                            acc.count += 1;
                            arena.accumulate(r, &mut acc.sum);
                            arena.accumulate_sq(r, &mut acc.sum2);
                            acc.sumsq += arena.data.sqnorm(r);
                        }
                    }
                }
                None => {
                    block::dists_contig_to_vec(arena, rows.clone(), center, c_sq, dists);
                    for (r, &d) in rows.zip(dists.iter()) {
                        if d <= radius {
                            acc.count += 1;
                            arena.accumulate(r, &mut acc.sum);
                            arena.accumulate_sq(r, &mut acc.sum2);
                            acc.sumsq += arena.data.sqnorm(r);
                        }
                    }
                }
            }
        }
    }
}

fn finish_moments(acc: MomentsAcc, dists: u64) -> BallMoments {
    let n = acc.count;
    let inv = if n == 0 { 0.0 } else { 1.0 / n as f64 };
    let mean: Vec<f32> = acc.sum.iter().map(|&s| (s * inv) as f32).collect();
    // Per-dimension variance identity: (1/n)Σxᵢ² − meanᵢ².
    let variance: Vec<f64> = acc
        .sum2
        .iter()
        .zip(&mean)
        .map(|(&s2, &m)| if n == 0 { 0.0 } else { (s2 * inv - (m as f64) * (m as f64)).max(0.0) })
        .collect();
    let mean_sq: f64 = mean.iter().map(|&m| (m as f64) * (m as f64)).sum();
    let total_variance = if n == 0 { 0.0 } else { (acc.sumsq * inv - mean_sq).max(0.0) };
    BallMoments { count: n, mean, variance, total_variance, dists }
}

fn finish(acc: Acc, dists: u64) -> BallStats {
    let n = acc.count;
    let inv = if n == 0 { 0.0 } else { 1.0 / n as f64 };
    let mean: Vec<f32> = acc.sum.iter().map(|&s| (s * inv) as f32).collect();
    // (1/n)Σ‖x‖² − ‖mean‖²  — the sufficient-statistics variance identity.
    let mean_sq: f64 = mean.iter().map(|&m| (m as f64) * (m as f64)).sum();
    let total_variance = if n == 0 { 0.0 } else { (acc.sumsq * inv - mean_sq).max(0.0) };
    BallStats { count: n, mean, total_variance, dists }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn clustered(seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for c in 0..6 {
            for _ in 0..120 {
                rows.push(vec![
                    ((c % 3) as f64 * 40.0 + rng.normal()) as f32,
                    ((c / 3) as f64 * 40.0 + rng.normal()) as f32,
                ]);
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn tree_matches_naive_exactly() {
        let space = clustered(1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        for (cx, cy, r) in [(0.0, 0.0, 3.0), (40.0, 0.0, 5.0), (20.0, 20.0, 60.0), (999.0, 999.0, 1.0)] {
            let center = vec![cx as f32, cy as f32];
            let a = naive_ball_stats(&space, &center, r);
            let b = tree_ball_stats(&space, &tree, &center, r);
            assert_eq!(a.count, b.count, "count at ({cx},{cy},{r})");
            for (x, y) in a.mean.iter().zip(&b.mean) {
                assert!((x - y).abs() < 1e-4, "mean {x} vs {y}");
            }
            assert!(
                (a.total_variance - b.total_variance).abs() < 1e-3 * (1.0 + a.total_variance),
                "variance {} vs {}",
                a.total_variance,
                b.total_variance
            );
        }
    }

    #[test]
    fn whole_cluster_query_uses_cached_stats() {
        let space = clustered(2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        // A ball containing one whole blob: far fewer distances than R.
        let center = vec![0.0f32, 0.0];
        let b = tree_ball_stats(&space, &tree, &center, 8.0);
        assert_eq!(b.count, 120);
        assert!(
            b.dists < space.n() as u64 / 3,
            "ball query used {} dists on {} points",
            b.dists,
            space.n()
        );
        // The blob's mean is ≈ (0,0) and per-point variance ≈ 2 (two unit
        // dimensions).
        assert!(b.mean[0].abs() < 0.3 && b.mean[1].abs() < 0.3);
        assert!((b.total_variance - 2.0).abs() < 0.5, "{}", b.total_variance);
    }

    #[test]
    fn empty_ball() {
        let space = clustered(3);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let b = tree_ball_stats(&space, &tree, &[500.0, 500.0], 1.0);
        assert_eq!(b.count, 0);
        assert_eq!(b.total_variance, 0.0);
    }

    #[test]
    fn everything_ball_matches_global_stats() {
        let space = clustered(4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let b = tree_ball_stats(&space, &tree, &[20.0, 20.0], 1e6);
        assert_eq!(b.count, space.n() as u64);
        let global_mean = space.centroid(&(0..space.n() as u32).collect::<Vec<_>>());
        for (x, y) in b.mean.iter().zip(&global_mean) {
            assert!((x - y).abs() < 1e-3);
        }
        // Root fully inside → O(1) node visits.
        assert!(b.dists <= 3, "used {} dists", b.dists);
    }

    #[test]
    fn moments_match_naive_and_direct_per_dim_variance() {
        let space = clustered(6);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        for (cx, cy, r) in [(0.0, 0.0, 6.0), (40.0, 40.0, 9.0), (20.0, 20.0, 80.0)] {
            let center = vec![cx as f32, cy as f32];
            let a = naive_ball_moments(&space, &center, r);
            let b = tree_ball_moments(&space, &tree, &center, r);
            assert_eq!(a.count, b.count, "count at ({cx},{cy},{r})");
            for (x, y) in a.mean.iter().zip(&b.mean) {
                assert!((x - y).abs() < 1e-4, "mean {x} vs {y}");
            }
            for (x, y) in a.variance.iter().zip(&b.variance) {
                assert!((x - y).abs() < 1e-3 * (1.0 + x), "variance {x} vs {y}");
            }
            // The variance diagonal sums to the total variance.
            let trace: f64 = b.variance.iter().sum();
            assert!(
                (trace - b.total_variance).abs() < 1e-6 * (1.0 + b.total_variance),
                "trace {trace} vs total {}",
                b.total_variance
            );
            // And matches a direct two-pass per-dimension computation.
            if a.count > 0 {
                let c_sq = dense_dot(&center, &center);
                let mut row = vec![0f32; 2];
                let mut direct = vec![0f64; 2];
                let mut m = 0u64;
                for p in 0..space.n() {
                    if space.dist_to_vec_uncounted(p, &center, c_sq) <= r {
                        m += 1;
                        space.fill_row(p, &mut row);
                        for (dv, (&v, &mu)) in
                            direct.iter_mut().zip(row.iter().zip(&a.mean))
                        {
                            let dx = v as f64 - mu as f64;
                            *dv += dx * dx;
                        }
                    }
                }
                assert_eq!(m, a.count);
                for (dv, &v) in direct.iter().zip(&b.variance) {
                    let dv = dv / m as f64;
                    assert!((dv - v).abs() < 1e-3 * (1.0 + dv), "direct {dv} vs cached {v}");
                }
            }
        }
    }

    #[test]
    fn whole_cluster_moments_use_cached_sum2() {
        let space = clustered(7);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        // A ball swallowing one blob answers from node stats: far fewer
        // distances than points, and per-dim variance ≈ 1 in both axes.
        let b = tree_ball_moments(&space, &tree, &[0.0, 0.0], 8.0);
        assert_eq!(b.count, 120);
        assert!(b.dists < space.n() as u64 / 3, "used {} dists", b.dists);
        for v in &b.variance {
            assert!((v - 1.0).abs() < 0.5, "per-dim variance {v}");
        }
    }

    #[test]
    fn variance_identity_against_direct_computation() {
        let space = clustered(5);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let center = vec![0.0f32, 0.0];
        let r = 4.0;
        let b = tree_ball_stats(&space, &tree, &center, r);
        // Direct two-pass variance.
        let c_sq = 0.0;
        let members: Vec<usize> = (0..space.n())
            .filter(|&p| space.dist_to_vec_uncounted(p, &center, c_sq) <= r)
            .collect();
        assert_eq!(members.len() as u64, b.count);
        let mut direct = 0.0;
        let mut row = vec![0f32; 2];
        for &p in &members {
            space.fill_row(p, &mut row);
            let dx = row[0] as f64 - b.mean[0] as f64;
            let dy = row[1] as f64 - b.mean[1] as f64;
            direct += dx * dx + dy * dy;
        }
        direct /= members.len() as f64;
        assert!(
            (direct - b.total_variance).abs() < 1e-3 * (1.0 + direct),
            "direct {direct} vs cached {}",
            b.total_variance
        );
    }
}
