//! K-means initialization strategies (Table 4 compares Random vs Anchors).

use crate::anchors::build_anchors_ex;
use crate::metrics::Space;
use crate::parallel::Executor;
use crate::rng::Rng;

/// Initialization strategy.
#[derive(Clone, Debug)]
pub enum Init {
    /// k distinct datapoints chosen uniformly at random.
    Random,
    /// Centroids of the k anchors produced by the anchors hierarchy —
    /// the paper's "Anchors Start".
    Anchors,
    /// Explicit seed centroids.
    Given(Vec<Vec<f32>>),
}

impl Init {
    /// Materialize the initial centroids. Distances used by the Anchors
    /// strategy ARE counted (they're real work), but callers measuring
    /// per-iteration cost snapshot the counter after init.
    pub fn centroids(&self, space: &Space, k: usize, seed: u64) -> Vec<Vec<f32>> {
        self.centroids_ex(space, k, seed, &Executor::serial())
    }

    /// [`Init::centroids`] with a worker budget: the Anchors strategy's
    /// O(R·√R)-distance hierarchy build fans out on `exec` (bit-identical
    /// seeds for every thread count); the other strategies are cheap and
    /// stay serial.
    pub fn centroids_ex(
        &self,
        space: &Space,
        k: usize,
        seed: u64,
        exec: &Executor,
    ) -> Vec<Vec<f32>> {
        match self {
            Init::Random => random_init(space, k, seed),
            Init::Anchors => anchors_init_ex(space, k, seed, exec),
            Init::Given(c) => {
                assert_eq!(c.len(), k, "Init::Given size mismatch");
                c.clone()
            }
        }
    }
}

/// k distinct random datapoints as seeds.
pub fn random_init(space: &Space, k: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let k = k.min(space.n());
    let idx = rng.sample_indices(space.n(), k);
    idx.into_iter()
        .map(|i| {
            let mut row = vec![0f32; space.dim()];
            space.fill_row(i, &mut row);
            row
        })
        .collect()
}

/// Build a k-anchor hierarchy and return each anchor's owned-set centroid
/// (paper §5, Table 4 "Anchors Start").
pub fn anchors_init(space: &Space, k: usize, seed: u64) -> Vec<Vec<f32>> {
    anchors_init_ex(space, k, seed, &Executor::serial())
}

/// [`anchors_init`] with the hierarchy build fanned out on `exec`.
pub fn anchors_init_ex(space: &Space, k: usize, seed: u64, exec: &Executor) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let points: Vec<u32> = (0..space.n() as u32).collect();
    let set = build_anchors_ex(space, &points, k, &mut rng, exec);
    let mut seeds = set.centroid_seeds(space);
    // If duplicates collapsed the anchor count below k, pad with random
    // points so the caller still gets k centroids.
    let mut i = 0;
    while seeds.len() < k {
        let mut row = vec![0f32; space.dim()];
        space.fill_row(rng.below(space.n()), &mut row);
        seeds.push(row);
        i += 1;
        if i > 4 * k {
            break;
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};

    fn space(n: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| vec![rng.normal() as f32, rng.normal() as f32])
            .collect();
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn random_init_distinct_points() {
        let s = space(100, 1);
        let seeds = random_init(&s, 10, 7);
        assert_eq!(seeds.len(), 10);
        for (i, a) in seeds.iter().enumerate() {
            for b in seeds.iter().skip(i + 1) {
                assert_ne!(a, b, "duplicate seeds");
            }
        }
    }

    #[test]
    fn random_init_deterministic() {
        let s = space(50, 2);
        assert_eq!(random_init(&s, 5, 9), random_init(&s, 5, 9));
        assert_ne!(random_init(&s, 5, 9), random_init(&s, 5, 10));
    }

    #[test]
    fn anchors_init_right_count() {
        let s = space(200, 3);
        let seeds = anchors_init(&s, 12, 11);
        assert_eq!(seeds.len(), 12);
        assert!(seeds.iter().all(|c| c.len() == 2));
    }

    #[test]
    fn given_passes_through() {
        let s = space(10, 4);
        let seeds = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let got = Init::Given(seeds.clone()).centroids(&s, 2, 0);
        assert_eq!(got, seeds);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn given_checks_k() {
        let s = space(10, 5);
        Init::Given(vec![vec![0.0, 0.0]]).centroids(&s, 2, 0);
    }

    #[test]
    fn k_clamped_to_n() {
        let s = space(4, 6);
        let seeds = random_init(&s, 10, 1);
        assert_eq!(seeds.len(), 4);
    }
}
