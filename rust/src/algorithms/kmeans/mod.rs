//! Exact K-means, naive and metric-tree-accelerated (paper §4.1).
//!
//! The accelerated pass (`KmeansStep` in the paper) recurses over the
//! tree carrying the candidate set `Cands` — the centroids that could
//! possibly own a point of the current node. Candidates are pruned with
//! the triangle-inequality blacklisting rule
//!
//! ```text
//! D(c*, pivot) + R ≤ D(c, pivot) − R   ⇒   c owns nothing in the node
//! ```
//!
//! and when one candidate remains the node's *cached sufficient
//! statistics* (count, Σx, Σ‖x‖²) are awarded wholesale — including the
//! exact distortion contribution — without touching a single point.
//!
//! Both paths produce identical assignments (tested); they differ only in
//! how many distances they evaluate, which is exactly what Table 2
//! measures.
//!
//! Both drivers honor [`KmeansOpts::parallelism`]: the naive pass fans
//! out over fixed point chunks and the tree pass over a fixed subtree
//! frontier, in both cases reducing per-worker accumulators in work-item
//! order — so every thread count yields bit-identical centroids,
//! distortion and distance counts (see [`crate::parallel`]).

mod init;

pub use init::{anchors_init, anchors_init_ex, random_init, Init};

use crate::metrics::{block, dense_dot, Space};
use crate::parallel::{Executor, Parallelism};
use crate::runtime::BatchDistanceEngine;
use crate::tree::{MetricTree, Node, NodeId};

/// Points per parallel work item in the chunked assignment passes.
/// Fixed — never a function of thread count — so partial accumulators
/// merge in the same order on every schedule (bit-reproducibility).
const ASSIGN_CHUNK: usize = 4096;

/// Options shared by the K-means drivers.
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    /// Stop when no centroid moves more than this (Euclidean).
    pub tol: f64,
    /// Use the XLA batch engine for dense distance blocks when provided.
    pub engine: Option<std::sync::Arc<BatchDistanceEngine>>,
    /// Seed for random initialization.
    pub seed: u64,
    /// Worker budget for the assignment passes (naive point chunks /
    /// tree frontier subtrees). Results are bit-identical for every
    /// setting; see [`crate::parallel`] for the determinism contract.
    pub parallelism: Parallelism,
}

impl Default for KmeansOpts {
    fn default() -> Self {
        KmeansOpts {
            tol: 1e-6,
            engine: None,
            seed: 0x5EED,
            parallelism: Parallelism::default(),
        }
    }
}

/// Result of a K-means run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub centroids: Vec<Vec<f32>>,
    /// Total distortion (Σ squared distance to owning centroid) of the
    /// final assignment.
    pub distortion: f64,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Distance computations consumed (excluding initialization).
    pub dists: u64,
}

/// Per-iteration accumulator.
struct Accum {
    counts: Vec<u64>,
    sums: Vec<Vec<f64>>,
    distortion: f64,
}

impl Accum {
    fn new(k: usize, d: usize) -> Self {
        Accum { counts: vec![0; k], sums: vec![vec![0.0; d]; k], distortion: 0.0 }
    }

    /// Fold another accumulator in. Counts are exact (integers); the
    /// float sums adopt the caller's merge order, so merging partials in
    /// work-item order keeps every pass deterministic.
    fn merge(&mut self, other: &Accum) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        for (s, os) in self.sums.iter_mut().zip(&other.sums) {
            for (v, ov) in s.iter_mut().zip(os) {
                *v += ov;
            }
        }
        self.distortion += other.distortion;
    }
}

/// Precomputed squared norms of the current centroids.
fn centroid_sqnorms(centroids: &[Vec<f32>]) -> Vec<f64> {
    // pallas-lint: allow(uncounted-dist, centroid norm staging reused by the counted kernels)
    centroids.iter().map(|c| dense_dot(c, c)).collect()
}

/// Recompute centroid positions from an accumulator; empty clusters keep
/// their old position (the paper's convention — no re-seeding, so the
/// naive and tree paths stay bit-identical). Returns max movement.
fn update_centroids(centroids: &mut [Vec<f32>], acc: &Accum) -> f64 {
    let mut max_move2 = 0.0f64;
    for (ci, c) in centroids.iter_mut().enumerate() {
        if acc.counts[ci] == 0 {
            continue;
        }
        let inv = 1.0 / acc.counts[ci] as f64;
        let mut move2 = 0.0;
        for (j, v) in c.iter_mut().enumerate() {
            let nv = (acc.sums[ci][j] * inv) as f32;
            let dlt = (nv - *v) as f64;
            move2 += dlt * dlt;
            *v = nv;
        }
        max_move2 = max_move2.max(move2);
    }
    max_move2.sqrt()
}

// ---------------------------------------------------------------------
// Naive (treeless) Lloyd iterations — the paper's "regular" baseline.
// ---------------------------------------------------------------------

/// One naive assignment pass: every point against every centroid
/// (R·K counted distances) through the blocked kernel, tile by tile.
/// Fans out over fixed [`ASSIGN_CHUNK`]-sized point chunks, each filling
/// a private accumulator; partials merge in chunk order, so the pass is
/// bit-identical at every thread count (and to the pointwise scan the
/// kernel replaces — see [`crate::metrics::block`]).
fn naive_pass(
    space: &Space,
    centroids: &[Vec<f32>],
    c_sq: &[f64],
    acc: &mut Accum,
    exec: &Executor,
) {
    let k = centroids.len();
    let d = space.dim();
    let ident: Vec<u32> = (0..k as u32).collect();
    let partials = exec.map_chunks(space.n(), ASSIGN_CHUNK, |range| {
        let mut part = Accum::new(k, d);
        let mut dists: Vec<f64> = Vec::new();
        let mut lo = range.start;
        while lo < range.end {
            let hi = (lo + block::TILE).min(range.end);
            space.checkpoint();
            space.obs().leaf_rows(crate::ids::u64_from_usize(hi - lo));
            block::dists_contig_to_centers(space, lo..hi, &ident, centroids, c_sq, &mut dists);
            for (ti, p) in (lo..hi).enumerate() {
                let row = &dists[ti * k..(ti + 1) * k];
                let mut best = f64::INFINITY;
                let mut best_c = 0usize;
                for (ci, &dist) in row.iter().enumerate() {
                    if dist < best {
                        best = dist;
                        best_c = ci;
                    }
                }
                part.counts[best_c] += 1;
                space.accumulate(p, &mut part.sums[best_c]);
                part.distortion += best * best;
            }
            lo = hi;
        }
        part
    });
    for part in &partials {
        acc.merge(part);
    }
}

/// One naive assignment pass routed through the XLA batch engine: the
/// whole R×K distance matrix is evaluated in (256 × 128)-tiles on the
/// PJRT CPU client. Counted identically (R·K).
fn naive_pass_xla(
    space: &Space,
    centroids: &[Vec<f32>],
    acc: &mut Accum,
    engine: &BatchDistanceEngine,
) {
    let n = space.n();
    let k = centroids.len();
    let tile = engine.tile_n();
    let mut block_rows: Vec<u32> = Vec::with_capacity(tile);
    let mut row = 0usize;
    while row < n {
        let hi = (row + tile).min(n);
        block_rows.clear();
        block_rows.extend((row as u32)..(hi as u32));
        let d2 = engine.dist2_block(space, &block_rows, centroids);
        space.checkpoint();
        space.count_bulk((block_rows.len() * k) as u64);
        space.obs().leaf_rows(crate::ids::u64_from_usize(block_rows.len()));
        for (bi, &p) in block_rows.iter().enumerate() {
            let drow = &d2[bi * k..(bi + 1) * k];
            let (mut best, mut best_c) = (f64::INFINITY, 0usize);
            for (ci, &v) in drow.iter().enumerate() {
                if (v as f64) < best {
                    best = v as f64;
                    best_c = ci;
                }
            }
            acc.counts[best_c] += 1;
            space.accumulate(p as usize, &mut acc.sums[best_c]);
            acc.distortion += best; // d2 is already squared
        }
        row = hi;
    }
}

/// Naive Lloyd's algorithm: `max_iters` full passes (or until centroids
/// stop moving). Builds a fresh executor from [`KmeansOpts::parallelism`];
/// callers that hold a long-lived pool (the engine facade) use
/// [`naive_lloyd_ex`].
pub fn naive_lloyd(
    space: &Space,
    init: Init,
    k: usize,
    max_iters: usize,
    opts: &KmeansOpts,
) -> KmeansResult {
    naive_lloyd_ex(space, init, k, max_iters, opts, &Executor::new(opts.parallelism))
}

/// [`naive_lloyd`] on an explicit executor, so repeated runs share one
/// persistent worker pool instead of re-resolving `opts.parallelism`.
pub fn naive_lloyd_ex(
    space: &Space,
    init: Init,
    k: usize,
    max_iters: usize,
    opts: &KmeansOpts,
    exec: &Executor,
) -> KmeansResult {
    let mut centroids = init.centroids_ex(space, k, opts.seed, exec);
    let before = space.dist_count();
    let d = space.dim();
    let mut iterations = 0;
    let mut distortion = f64::NAN;
    for _ in 0..max_iters {
        let c_sq = centroid_sqnorms(&centroids);
        let mut acc = Accum::new(centroids.len(), d);
        match (&opts.engine, space.data.is_sparse()) {
            (Some(engine), false) => naive_pass_xla(space, &centroids, &mut acc, engine),
            _ => naive_pass(space, &centroids, &c_sq, &mut acc, exec),
        }
        iterations += 1;
        distortion = acc.distortion;
        let moved = update_centroids(&mut centroids, &acc);
        if moved <= opts.tol {
            break;
        }
    }
    KmeansResult {
        centroids,
        distortion,
        iterations,
        dists: space.dist_count() - before,
    }
}

// ---------------------------------------------------------------------
// Tree-accelerated Lloyd iterations (the paper's KmeansStep).
// ---------------------------------------------------------------------

/// Scratch shared across the recursion of one pass.
struct StepCtx<'a> {
    space: &'a Space,
    tree: &'a MetricTree,
    /// The tree-order arena: every leaf is one contiguous row range
    /// here, so leaf assignment streams a sequential slab instead of
    /// gathering scattered rows. Shares `space`'s distance counter.
    arena: &'a Space,
    centroids: &'a [Vec<f32>],
    c_sq: &'a [f64],
    engine: Option<&'a BatchDistanceEngine>,
}

/// Allocation-free candidate storage for the recursion: candidate sets
/// live as stacked ranges of one growable vec (each node pushes its kept
/// set, recurses, then truncates) — the hot loop performs zero heap
/// allocations after the first pass (docs/EXPERIMENTS.md §Perf).
struct StepScratch {
    cands: Vec<u32>,
    dists: Vec<f64>,
    /// Blocked-kernel output buffer for leaf assignment (row-major
    /// points × candidates), reused across every leaf of the pass.
    block: Vec<f64>,
    /// Arena row-id buffer for the XLA leaf path (its API takes
    /// `&[u32]`), reused across leaves so the hot loop stays
    /// allocation-free.
    row_ids: Vec<u32>,
}

/// Step 1 of the paper's KmeansStep: prune the candidate range `lo..hi`
/// against `node` with the blacklisting rule, pushing the surviving set
/// onto the top of `scratch.cands`. Returns the surviving range.
fn reduce_cands(
    ctx: &StepCtx,
    node: &Node,
    lo: usize,
    hi: usize,
    scratch: &mut StepScratch,
) -> (usize, usize) {
    // Distances from every candidate to the node pivot (counted).
    if scratch.dists.len() < hi {
        scratch.dists.resize(hi, 0.0);
    }
    ctx.space.count_bulk((hi - lo) as u64);
    let mut star_pos = lo;
    let mut star_dist = f64::INFINITY;
    for i in lo..hi {
        let cu = scratch.cands[i] as usize;
        let d2 = ctx.c_sq[cu] + node.pivot_sq
            // pallas-lint: allow(uncounted-dist, counted via count_bulk at loop entry above)
            - 2.0 * dense_dot(&ctx.centroids[cu], &node.pivot);
        let d = d2.max(0.0).sqrt();
        scratch.dists[i] = d;
        if d < star_dist {
            star_dist = d;
            star_pos = i;
        }
    }
    let keep_threshold = star_dist + 2.0 * node.radius; // D(c,p) - R >= D(c*,p) + R
    let new_lo = scratch.cands.len();
    for i in lo..hi {
        if scratch.dists[i] < keep_threshold || i == star_pos {
            let c = scratch.cands[i];
            scratch.cands.push(c);
        }
    }
    (new_lo, scratch.cands.len())
}

/// Award a whole node to candidate `c`: cached sufficient statistics
/// deliver count, Σx and the exact distortion contribution in O(d).
/// Each award is one triangle-blacklisting prune — the subtree below is
/// settled without touching a point.
fn award_node(ctx: &StepCtx, node: &Node, c: usize, acc: &mut Accum) {
    ctx.space.obs().prune(crate::obs::PruneRule::Triangle);
    acc.counts[c] += node.count as u64;
    for (j, s) in node.sum.iter().enumerate() {
        acc.sums[c][j] += s;
    }
    acc.distortion += node.distortion_to(&ctx.centroids[c], ctx.c_sq[c]);
}

/// One tree pass. `lo..hi` indexes this node's candidate set inside
/// `scratch.cands`. `depth` is the node's tree depth (root = 0), used
/// only for observability fan-out attribution.
#[allow(clippy::too_many_arguments)]
fn kmeans_step(
    ctx: &StepCtx,
    node_id: NodeId,
    lo: usize,
    hi: usize,
    depth: usize,
    scratch: &mut StepScratch,
    acc: &mut Accum,
) {
    let node = ctx.tree.node(node_id);
    debug_assert!(hi > lo);
    ctx.space.checkpoint();
    ctx.space.obs().visit(depth);
    let (new_lo, new_hi) = reduce_cands(ctx, node, lo, hi, scratch);

    // ---- Step 2: award mass ----------------------------------------
    if new_hi - new_lo == 1 {
        // Whole node belongs to the surviving candidate.
        award_node(ctx, node, scratch.cands[new_lo] as usize, acc);
        scratch.cands.truncate(new_lo);
        return;
    }
    match node.children {
        Some((a, b)) => {
            kmeans_step(ctx, a, new_lo, new_hi, depth + 1, scratch, acc);
            kmeans_step(ctx, b, new_lo, new_hi, depth + 1, scratch, acc);
        }
        None => {
            let StepScratch { cands, block, row_ids, .. } = scratch;
            leaf_assign(ctx, node_id, &cands[new_lo..new_hi], acc, block, row_ids);
        }
    }
    scratch.cands.truncate(new_lo);
}

// ---------------------------------------------------------------------
// Parallel decomposition of one tree pass.
//
// The node-award traversal partitions the tree at a *fixed* frontier
// (depth- and size-bounded, never thread-count-dependent): the serial
// collector walks the top of the tree doing exactly the work kmeans_step
// would — pruning candidates, awarding single-candidate nodes, assigning
// shallow leaves — and emits one task per surviving subtree pair. Tasks
// then run on the executor with per-worker accumulators that are reduced
// in task order, so the pass is bit-identical at every thread count and
// its counted distances are exactly the serial traversal's.
// ---------------------------------------------------------------------

/// A unit of parallel work: the two children of a node whose candidate
/// set is already reduced.
struct StepTask {
    children: (NodeId, NodeId),
    cands: Vec<u32>,
    /// Tree depth of the two children (for fan-out attribution), so the
    /// parallel recursion reports the same per-level counts the serial
    /// pass would.
    depth: usize,
}

/// Subtrees at or below this point count stay whole (one task).
const STEP_TASK_GRAIN: u32 = 512;
/// Maximum frontier depth: at most 2^STEP_FRONTIER_DEPTH tasks per pass.
const STEP_FRONTIER_DEPTH: usize = 8;

/// Walk the top of the tree exactly as [`kmeans_step`] would, emitting a
/// [`StepTask`] wherever the remaining subtree is small or deep enough;
/// awards and shallow-leaf assignments accumulate into `acc` in DFS
/// order (the same order the serial pass uses).
#[allow(clippy::too_many_arguments)]
fn collect_step_tasks(
    ctx: &StepCtx,
    node_id: NodeId,
    lo: usize,
    hi: usize,
    depth: usize,
    scratch: &mut StepScratch,
    acc: &mut Accum,
    tasks: &mut Vec<StepTask>,
) {
    let node = ctx.tree.node(node_id);
    debug_assert!(hi > lo);
    // `depth` counts DOWN from STEP_FRONTIER_DEPTH (a frontier budget);
    // the node's tree depth counts up from the root.
    let tree_depth = STEP_FRONTIER_DEPTH - depth;
    ctx.space.checkpoint();
    ctx.space.obs().visit(tree_depth);
    let (new_lo, new_hi) = reduce_cands(ctx, node, lo, hi, scratch);
    if new_hi - new_lo == 1 {
        award_node(ctx, node, scratch.cands[new_lo] as usize, acc);
        scratch.cands.truncate(new_lo);
        return;
    }
    match node.children {
        Some((a, b)) => {
            if depth == 0 || node.count <= STEP_TASK_GRAIN {
                tasks.push(StepTask {
                    children: (a, b),
                    cands: scratch.cands[new_lo..new_hi].to_vec(),
                    depth: tree_depth + 1,
                });
            } else {
                collect_step_tasks(ctx, a, new_lo, new_hi, depth - 1, scratch, acc, tasks);
                collect_step_tasks(ctx, b, new_lo, new_hi, depth - 1, scratch, acc, tasks);
            }
        }
        None => {
            let StepScratch { cands, block, row_ids, .. } = scratch;
            leaf_assign(ctx, node_id, &cands[new_lo..new_hi], acc, block, row_ids);
        }
    }
    scratch.cands.truncate(new_lo);
}

/// Run one frontier task: a standard [`kmeans_step`] recursion over each
/// child with a private scratch and accumulator.
fn run_step_task(ctx: &StepCtx, task: &StepTask) -> Accum {
    let mut acc = Accum::new(ctx.centroids.len(), ctx.space.dim());
    let n0 = task.cands.len();
    let mut scratch = StepScratch {
        cands: task.cands.clone(),
        dists: vec![0.0; n0],
        block: Vec::new(),
        row_ids: Vec::new(),
    };
    let (a, b) = task.children;
    kmeans_step(ctx, a, 0, n0, task.depth, &mut scratch, &mut acc);
    kmeans_step(ctx, b, 0, n0, task.depth, &mut scratch, &mut acc);
    debug_assert_eq!(scratch.cands.len(), n0, "task scratch stack leaked");
    acc
}

/// Assign the points of a leaf among the surviving candidates.
fn leaf_assign(
    ctx: &StepCtx,
    node_id: NodeId,
    cands: &[u32],
    acc: &mut Accum,
    dists: &mut Vec<f64>,
    row_ids: &mut Vec<u32>,
) {
    let rows = ctx.tree.node_rows(node_id);
    ctx.space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
    // Dense data + engine + big enough block → XLA tile; else the
    // contiguous scalar kernel (bit-identical to the pointwise scan).
    // Either way the rows come from the tree-order arena — one
    // sequential slab per leaf, no gather.
    if let (Some(engine), false) = (ctx.engine, ctx.arena.data.is_sparse()) {
        if rows.len() * cands.len() >= engine.min_block() {
            let cents: Vec<Vec<f32>> = cands
                .iter()
                .map(|&c| ctx.centroids[c as usize].clone())
                .collect();
            row_ids.clear();
            row_ids.extend(rows.start as u32..rows.end as u32);
            let d2 = engine.dist2_block(ctx.arena, row_ids, &cents);
            ctx.arena.count_bulk((rows.len() * cands.len()) as u64);
            for (pi, r) in rows.enumerate() {
                let row = &d2[pi * cands.len()..(pi + 1) * cands.len()];
                let (mut best, mut best_c) = (f64::INFINITY, 0u32);
                for (ci, &v) in row.iter().enumerate() {
                    if (v as f64) < best {
                        best = v as f64;
                        best_c = cands[ci];
                    }
                }
                let bc = best_c as usize;
                acc.counts[bc] += 1;
                ctx.arena.accumulate(r, &mut acc.sums[bc]);
                acc.distortion += best;
            }
            return;
        }
    }
    block::dists_contig_to_centers(ctx.arena, rows.clone(), cands, ctx.centroids, ctx.c_sq, dists);
    for (pi, r) in rows.enumerate() {
        let row = &dists[pi * cands.len()..(pi + 1) * cands.len()];
        let (mut best, mut best_c) = (f64::INFINITY, 0u32);
        for (&c, &d) in cands.iter().zip(row) {
            if d < best {
                best = d;
                best_c = c;
            }
        }
        let bc = best_c as usize;
        acc.counts[bc] += 1;
        ctx.arena.accumulate(r, &mut acc.sums[bc]);
        acc.distortion += best * best;
    }
}

/// Tree-accelerated Lloyd's algorithm. Builds a fresh executor from
/// [`KmeansOpts::parallelism`]; callers that hold a long-lived pool use
/// [`tree_lloyd_ex`].
pub fn tree_lloyd(
    space: &Space,
    tree: &MetricTree,
    init: Init,
    k: usize,
    max_iters: usize,
    opts: &KmeansOpts,
) -> KmeansResult {
    tree_lloyd_ex(space, tree, init, k, max_iters, opts, &Executor::new(opts.parallelism))
}

/// [`tree_lloyd`] on an explicit executor, so every iteration's frontier
/// fan-out reuses one persistent worker pool.
#[allow(clippy::too_many_arguments)]
pub fn tree_lloyd_ex(
    space: &Space,
    tree: &MetricTree,
    init: Init,
    k: usize,
    max_iters: usize,
    opts: &KmeansOpts,
    exec: &Executor,
) -> KmeansResult {
    let mut centroids = init.centroids_ex(space, k, opts.seed, exec);
    let before = space.dist_count();
    let d = space.dim();
    let mut scratch = StepScratch {
        cands: (0..centroids.len() as u32).collect(),
        dists: vec![0.0; centroids.len()],
        block: Vec::new(),
        row_ids: Vec::new(),
    };
    let n_cands = scratch.cands.len();
    let mut iterations = 0;
    let mut distortion = f64::NAN;
    for _ in 0..max_iters {
        let c_sq = centroid_sqnorms(&centroids);
        let mut acc = Accum::new(centroids.len(), d);
        let ctx = StepCtx {
            space,
            tree,
            arena: tree.arena(),
            centroids: &centroids,
            c_sq: &c_sq,
            engine: opts.engine.as_deref(),
        };
        let mut tasks: Vec<StepTask> = Vec::new();
        collect_step_tasks(
            &ctx,
            tree.root,
            0,
            n_cands,
            STEP_FRONTIER_DEPTH,
            &mut scratch,
            &mut acc,
            &mut tasks,
        );
        debug_assert_eq!(scratch.cands.len(), n_cands, "scratch stack leaked");
        let partials = exec.map_tasks(tasks.len(), |i| run_step_task(&ctx, &tasks[i]));
        for part in &partials {
            acc.merge(part);
        }
        iterations += 1;
        distortion = acc.distortion;
        let moved = update_centroids(&mut centroids, &acc);
        if moved <= opts.tol {
            break;
        }
    }
    KmeansResult {
        centroids,
        distortion,
        iterations,
        dists: space.dist_count() - before,
    }
}

/// Final assignment of every point to its centroid (for consumers that
/// need explicit labels; not part of the counted benchmark loop).
pub fn assign_labels(space: &Space, centroids: &[Vec<f32>]) -> Vec<u32> {
    assign_labels_ex(space, centroids, &Executor::serial())
}

/// [`assign_labels`] fanned out over point chunks; the label vector is
/// identical for every thread count (each point's label is independent).
pub fn assign_labels_ex(space: &Space, centroids: &[Vec<f32>], exec: &Executor) -> Vec<u32> {
    let c_sq = centroid_sqnorms(centroids);
    let mut labels = Vec::with_capacity(space.n());
    for chunk in exec.map_chunks(space.n(), ASSIGN_CHUNK, |range| {
        range
            .map(|p| {
                let mut best = f64::INFINITY;
                let mut best_c = 0u32;
                for (ci, c) in centroids.iter().enumerate() {
                    // pallas-lint: allow(uncounted-dist, label readout; documented uncounted reporting pass)
                    let d = space.dist_to_vec_uncounted(p, c, c_sq[ci]);
                    if d < best {
                        best = d;
                        best_c = ci as u32;
                    }
                }
                best_c
            })
            .collect::<Vec<u32>>()
    }) {
        labels.extend(chunk);
    }
    labels
}

/// Distortion of an arbitrary centroid set (uncounted; reporting only).
pub fn distortion_of(space: &Space, centroids: &[Vec<f32>]) -> f64 {
    let c_sq = centroid_sqnorms(centroids);
    (0..space.n())
        .map(|p| {
            centroids
                .iter()
                .enumerate()
                // pallas-lint: allow(uncounted-dist, documented uncounted; reporting only)
                .map(|(ci, c)| space.dist_to_vec_uncounted(p, c, c_sq[ci]).powi(2))
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};
    use crate::tree::top_down;

    fn blobs(c: usize, per: usize, d: usize, seed: u64) -> Space {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for _ in 0..c {
            let center: Vec<f64> = (0..d).map(|_| rng.uniform(-40.0, 40.0)).collect();
            for _ in 0..per {
                rows.push(
                    center
                        .iter()
                        .map(|&cv| (cv + rng.normal()) as f32)
                        .collect::<Vec<f32>>(),
                );
            }
        }
        Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)))
    }

    #[test]
    fn naive_and_tree_agree_exactly() {
        // The core exactness claim: same init ⇒ same distortion trajectory.
        let space = blobs(5, 80, 3, 1);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        for k in [3usize, 7, 12] {
            let opts = KmeansOpts::default();
            let a = naive_lloyd(&space, Init::Random, k, 10, &opts);
            let b = tree_lloyd(&space, &tree, Init::Random, k, 10, &opts);
            assert!(
                (a.distortion - b.distortion).abs() <= 1e-6 * (1.0 + a.distortion),
                "k={k}: naive {} vs tree {}",
                a.distortion,
                b.distortion
            );
            // Same final centroids.
            for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
                for (x, y) in ca.iter().zip(cb) {
                    assert!((x - y).abs() < 1e-4, "centroid drift {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn tree_uses_fewer_distances() {
        let space = blobs(8, 150, 2, 2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 25, ..Default::default() });
        let opts = KmeansOpts::default();
        let a = naive_lloyd(&space, Init::Random, 8, 8, &opts);
        let b = tree_lloyd(&space, &tree, Init::Random, 8, 8, &opts);
        assert!(
            b.dists * 3 < a.dists,
            "tree {} vs naive {} distances",
            b.dists,
            a.dists
        );
    }

    #[test]
    fn works_with_top_down_tree_too() {
        let space = blobs(4, 60, 3, 3);
        let tree = top_down::build(&space, 20);
        let opts = KmeansOpts::default();
        let a = naive_lloyd(&space, Init::Random, 4, 6, &opts);
        let b = tree_lloyd(&space, &tree, Init::Random, 4, 6, &opts);
        assert!((a.distortion - b.distortion).abs() <= 1e-6 * (1.0 + a.distortion));
    }

    #[test]
    fn distortion_decreases_monotonically() {
        let space = blobs(6, 60, 2, 4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let opts = KmeansOpts::default();
        let mut prev = f64::INFINITY;
        for iters in [1usize, 2, 4, 8] {
            let r = tree_lloyd(&space, &tree, Init::Random, 6, iters, &opts);
            assert!(
                r.distortion <= prev + 1e-6 * (1.0 + prev),
                "distortion rose: {prev} -> {}",
                r.distortion
            );
            prev = r.distortion;
        }
    }

    #[test]
    fn anchors_init_beats_random_before_iterations() {
        // Table 4's "Start Benefit": anchors-chosen seeds have lower
        // distortion than random seeds.
        let space = blobs(10, 100, 3, 5);
        let k = 10;
        let random = random_init(&space, k, 99);
        let anchors = anchors_init(&space, k, 99);
        let dr = distortion_of(&space, &random);
        let da = distortion_of(&space, &anchors);
        assert!(
            da < dr,
            "anchors start {da} not better than random start {dr}"
        );
    }

    #[test]
    fn empty_cluster_keeps_position() {
        // Two far-apart seeds, all data near one of them.
        let rows: Vec<Vec<f32>> = (0..50)
            .map(|i| vec![(i % 5) as f32 * 0.01, 0.0])
            .collect();
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let seeds = vec![vec![0.0f32, 0.0], vec![1000.0f32, 1000.0]];
        let r = naive_lloyd(&space, Init::Given(seeds.clone()), 2, 5, &KmeansOpts::default());
        // Far seed owns nothing and must not move.
        assert_eq!(r.centroids[1], seeds[1]);
    }

    #[test]
    fn single_cluster_k1() {
        let space = blobs(3, 40, 2, 6);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let r = tree_lloyd(&space, &tree, Init::Random, 1, 5, &KmeansOpts::default());
        // k=1: centroid converges to the global mean.
        let mean = space.centroid(&(0..space.n() as u32).collect::<Vec<_>>());
        for (a, b) in r.centroids[0].iter().zip(&mean) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn k_greater_than_distinct_points() {
        let rows: Vec<Vec<f32>> = vec![vec![0.0, 0.0], vec![1.0, 1.0]];
        let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
        let r = naive_lloyd(&space, Init::Random, 5, 3, &KmeansOpts::default());
        assert!(r.distortion >= 0.0);
    }

    #[test]
    fn labels_match_distortion() {
        let space = blobs(4, 30, 2, 7);
        let r = naive_lloyd(&space, Init::Random, 4, 10, &KmeansOpts::default());
        let labels = assign_labels(&space, &r.centroids);
        let c_sq = centroid_sqnorms(&r.centroids);
        let manual: f64 = (0..space.n())
            .map(|p| {
                space
                    .dist_to_vec_uncounted(p, &r.centroids[labels[p] as usize], c_sq[labels[p] as usize])
                    .powi(2)
            })
            .sum();
        assert!((manual - r.distortion).abs() < 1e-5 * (1.0 + manual));
    }

    #[test]
    fn thread_count_does_not_change_a_single_bit() {
        // The parallel decomposition contract: naive and tree passes
        // produce bit-identical centroids, distortion and distance
        // counts at every thread count.
        let space = blobs(6, 120, 4, 21);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let run = |parallelism: Parallelism| {
            let opts = KmeansOpts { parallelism, ..Default::default() };
            let naive = naive_lloyd(&space, Init::Random, 7, 6, &opts);
            let tree_r = tree_lloyd(&space, &tree, Init::Random, 7, 6, &opts);
            (naive, tree_r)
        };
        let (n1, t1) = run(Parallelism::Serial);
        for threads in [2usize, 8] {
            let (nt, tt) = run(Parallelism::Fixed(threads));
            assert_eq!(n1.centroids, nt.centroids, "naive centroids, {threads} threads");
            assert_eq!(
                n1.distortion.to_bits(),
                nt.distortion.to_bits(),
                "naive distortion, {threads} threads"
            );
            assert_eq!(n1.dists, nt.dists, "naive dists, {threads} threads");
            assert_eq!(t1.centroids, tt.centroids, "tree centroids, {threads} threads");
            assert_eq!(
                t1.distortion.to_bits(),
                tt.distortion.to_bits(),
                "tree distortion, {threads} threads"
            );
            assert_eq!(t1.dists, tt.dists, "tree dists, {threads} threads");
        }
    }

    #[test]
    fn sparse_data_kmeans() {
        use crate::dataset::gen_mixture;
        let m = gen_mixture(400, 200, 3, 8);
        let space = Space::euclidean(Data::Sparse(m));
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 20, ..Default::default() });
        let opts = KmeansOpts::default();
        let a = naive_lloyd(&space, Init::Random, 3, 6, &opts);
        let b = tree_lloyd(&space, &tree, Init::Random, 3, 6, &opts);
        assert!((a.distortion - b.distortion).abs() <= 1e-5 * (1.0 + a.distortion));
    }
}
