//! Mixtures of spherical Gaussians with tree-accelerated EM (paper §6,
//! following the mrkd-tree acceleration of Moore, NIPS 1999).
//!
//! The E-step computes responsibilities `r_k(x) ∝ π_k N(x; μ_k, σ_k² I)`.
//! For a tree node, the distance from any owned point to μ_k lies in
//! `[max(0, D(pivot, μ_k) − radius), D(pivot, μ_k) + radius]`, which
//! brackets every responsibility. When the bracket is tight for all
//! components the whole node's mass is assigned using its cached
//! sufficient statistics; otherwise we recurse. With `tau = 0` the result
//! is exact (bit-comparable to naive EM up to summation order).

use crate::metrics::{block, dense_dot, Space};
use crate::tree::{MetricTree, NodeId};

/// Spherical-Gaussian mixture parameters.
#[derive(Clone, Debug)]
pub struct Mixture {
    pub weights: Vec<f64>,
    pub means: Vec<Vec<f32>>,
    /// Per-component isotropic variance σ².
    pub variances: Vec<f64>,
}

impl Mixture {
    pub fn k(&self) -> usize {
        self.weights.len()
    }

    /// Initialize from K-means-style seeds with unit variance.
    pub fn from_seeds(seeds: Vec<Vec<f32>>) -> Mixture {
        let k = seeds.len();
        Mixture {
            weights: vec![1.0 / k as f64; k],
            means: seeds,
            variances: vec![1.0; k],
        }
    }
}

/// Accumulated E-step sufficient statistics.
struct EmAccum {
    /// Σ_x r_k(x)
    resp: Vec<f64>,
    /// Σ_x r_k(x)·x
    wsum: Vec<Vec<f64>>,
    /// Σ_x r_k(x)·‖x‖²
    wsumsq: Vec<f64>,
    loglik: f64,
}

impl EmAccum {
    fn new(k: usize, d: usize) -> Self {
        EmAccum {
            resp: vec![0.0; k],
            wsum: vec![vec![0.0; d]; k],
            wsumsq: vec![0.0; k],
            loglik: 0.0,
        }
    }
}

/// Log of the (unnormalized) component density at squared distance `d2`.
#[inline]
fn log_weight(pi: f64, var: f64, d2: f64, dim: usize) -> f64 {
    pi.ln() - 0.5 * dim as f64 * (2.0 * std::f64::consts::PI * var).ln() - d2 / (2.0 * var)
}

/// One naive E-step (R·K counted distances) + M-step. Returns loglik.
pub fn naive_em_step(space: &Space, mix: &mut Mixture) -> f64 {
    let k = mix.k();
    let d = space.dim();
    // pallas-lint: allow(uncounted-dist, centroid norm staging; the R*K E-step distances are counted below)
    let m_sq: Vec<f64> = mix.means.iter().map(|m| dense_dot(m, m)).collect();
    let mut acc = EmAccum::new(k, d);
    let mut logw = vec![0f64; k];
    space.obs().leaf_rows(crate::ids::u64_from_usize(space.n()));
    for p in 0..space.n() {
        if p % block::SCAN_CHUNK == 0 {
            space.checkpoint();
        }
        for c in 0..k {
            let dist = space.dist_to_vec(p, &mix.means[c], m_sq[c]);
            logw[c] = log_weight(mix.weights[c], mix.variances[c], dist * dist, d);
        }
        accumulate_point(space, p, &logw, &mut acc);
    }
    m_step(space, mix, &acc);
    acc.loglik
}

/// Scratch reused across every leaf of one tree E-step: the identity
/// candidate list (every component scores every leaf point), the
/// contiguous-kernel output block and the per-point log-weight row.
struct EmScratch {
    ident: Vec<u32>,
    dists: Vec<f64>,
    logw: Vec<f64>,
}

/// One tree E-step + M-step. `tau` bounds the allowed responsibility
/// bracket width before a node is awarded in bulk (0 = exact).
pub fn tree_em_step(space: &Space, tree: &MetricTree, mix: &mut Mixture, tau: f64) -> f64 {
    let k = mix.k();
    let d = space.dim();
    // pallas-lint: allow(uncounted-dist, centroid norm staging; node distances counted in recurse)
    let m_sq: Vec<f64> = mix.means.iter().map(|m| dense_dot(m, m)).collect();
    let mut acc = EmAccum::new(k, d);
    let mut scratch = EmScratch {
        ident: (0..k as u32).collect(),
        dists: Vec::new(),
        logw: vec![0f64; k],
    };
    recurse(space, tree, tree.root, mix, &m_sq, tau, 0, &mut acc, &mut scratch);
    m_step(space, mix, &acc);
    acc.loglik
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    space: &Space,
    tree: &MetricTree,
    id: NodeId,
    mix: &Mixture,
    m_sq: &[f64],
    tau: f64,
    depth: usize,
    acc: &mut EmAccum,
    scratch: &mut EmScratch,
) {
    let node = tree.node(id);
    let k = mix.k();
    let dim = space.dim();
    space.checkpoint();
    space.obs().visit(depth);
    // Bracket log-weights over the node's ball (k counted distances).
    let mut lo = vec![0f64; k];
    let mut hi = vec![0f64; k];
    let mut center = vec![0f64; k];
    for c in 0..k {
        space.count_bulk(1);
        // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
        let d2c = m_sq[c] + node.pivot_sq - 2.0 * dense_dot(&mix.means[c], &node.pivot);
        let dp = d2c.max(0.0).sqrt();
        let dmin = (dp - node.radius).max(0.0);
        let dmax = dp + node.radius;
        lo[c] = log_weight(mix.weights[c], mix.variances[c], dmax * dmax, dim);
        hi[c] = log_weight(mix.weights[c], mix.variances[c], dmin * dmin, dim);
        center[c] = log_weight(mix.weights[c], mix.variances[c], dp * dp, dim);
    }
    // Responsibility brackets in ratio form:
    //   r_k(x) = 1 / (1 + Σ_{c≠k} w_c(x)/w_k(x)),
    // and over the ball  w_c/w_k ≤ exp(hi_c − lo_k),  ≥ exp(lo_c − hi_k).
    // Anchoring numerator and denominator at the same x makes this far
    // tighter than bounding w_c and Σw independently.
    let mut tight = node.radius.is_finite();
    for c in 0..k {
        let mut ratio_hi = 0.0f64; // Σ upper bounds on w_j/w_c
        let mut ratio_lo = 0.0f64; // Σ lower bounds
        for j in 0..k {
            if j == c {
                continue;
            }
            ratio_hi += (hi[j] - lo[c]).min(500.0).exp();
            ratio_lo += (lo[j] - hi[c]).max(-500.0).exp();
        }
        let r_lo = 1.0 / (1.0 + ratio_hi);
        let r_hi = 1.0 / (1.0 + ratio_lo);
        if r_hi - r_lo > tau {
            tight = false;
            break;
        }
    }
    // tau == 0 means exact mode: never award in bulk (the bulk award uses
    // pivot-centered responsibilities, which is an approximation even when
    // the bracket is numerically degenerate-tight).
    if tight && tau > 0.0 && !node.is_leaf() {
        // Responsibility bracket closed within tau: the bulk award is a
        // budget-style prune (approximation budget, not a triangle cut).
        space.obs().prune(crate::obs::PruneRule::Budget);
        award_node(space, node, &center, acc);
        return;
    }
    match node.children {
        Some((a, b)) => {
            recurse(space, tree, a, mix, m_sq, tau, depth + 1, acc, scratch);
            recurse(space, tree, b, mix, m_sq, tau, depth + 1, acc, scratch);
        }
        None => {
            // Leaf E-step on the tree-order arena: one contiguous
            // kernel call delivers the full |leaf| × k distance block
            // (bit-identical values, same |leaf|·k count as the
            // pointwise loop), then responsibilities accumulate per
            // row exactly as before.
            let arena = tree.arena();
            let rows = tree.node_rows(id);
            space.obs().leaf_rows(crate::ids::u64_from_usize(rows.len()));
            block::dists_contig_to_centers(
                arena,
                rows.clone(),
                &scratch.ident,
                &mix.means,
                m_sq,
                &mut scratch.dists,
            );
            for (t, r) in rows.enumerate() {
                let drow = &scratch.dists[t * k..(t + 1) * k];
                for c in 0..k {
                    scratch.logw[c] =
                        log_weight(mix.weights[c], mix.variances[c], drow[c] * drow[c], dim);
                }
                accumulate_point(arena, r, &scratch.logw, acc);
            }
        }
    }
}

/// Award an entire node using responsibilities evaluated at the pivot.
fn award_node(space: &Space, node: &crate::tree::Node, center_logw: &[f64], acc: &mut EmAccum) {
    let _ = space;
    let max = center_logw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let sum: f64 = center_logw.iter().map(|&v| (v - max).exp()).sum();
    let count = node.count as f64;
    acc.loglik += count * (max + sum.ln());
    for (c, &lw) in center_logw.iter().enumerate() {
        let r = (lw - max).exp() / sum;
        acc.resp[c] += r * count;
        for (j, s) in node.sum.iter().enumerate() {
            acc.wsum[c][j] += r * s;
        }
        acc.wsumsq[c] += r * node.sumsq;
    }
}

fn accumulate_point(space: &Space, p: usize, logw: &[f64], acc: &mut EmAccum) {
    let max = logw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let sum: f64 = logw.iter().map(|&v| (v - max).exp()).sum();
    acc.loglik += max + sum.ln();
    let psq = space.data.sqnorm(p);
    for (c, &lw) in logw.iter().enumerate() {
        let r = (lw - max).exp() / sum;
        acc.resp[c] += r;
        acc.wsumsq[c] += r * psq;
    }
    // Single data pass for the weighted sums.
    // (accumulate() adds x once; scale per component via responsibility.)
    for c in 0..logw.len() {
        let r = (logw[c] - max).exp() / sum;
        if r > 0.0 {
            scaled_accumulate(space, p, r, &mut acc.wsum[c]);
        }
    }
}

fn scaled_accumulate(space: &Space, i: usize, scale: f64, acc: &mut [f64]) {
    use crate::data::Data;
    match &space.data {
        Data::Dense(m) => {
            // pallas-lint: allow(uncounted-dist, sufficient-statistics accumulation; no distance computed)
            for (a, &v) in acc.iter_mut().zip(m.row(i)) {
                *a += scale * v as f64;
            }
        }
        Data::Sparse(m) => {
            // pallas-lint: allow(uncounted-dist, sufficient-statistics accumulation; no distance computed)
            let (idx, val) = m.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                acc[j as usize] += scale * v as f64;
            }
        }
    }
}

/// M-step: closed-form updates from the accumulated statistics.
fn m_step(space: &Space, mix: &mut Mixture, acc: &EmAccum) {
    let n = space.n() as f64;
    let d = space.dim() as f64;
    for c in 0..mix.k() {
        let r = acc.resp[c];
        if r < 1e-12 {
            continue; // dead component keeps its parameters
        }
        mix.weights[c] = r / n;
        let mut mean_sq = 0.0f64;
        for (j, m) in mix.means[c].iter_mut().enumerate() {
            let nv = acc.wsum[c][j] / r;
            *m = nv as f32;
            mean_sq += nv * nv;
        }
        // E[‖x‖²] − ‖μ‖², per dimension.
        let var = (acc.wsumsq[c] / r - mean_sq) / d;
        mix.variances[c] = var.max(1e-6);
    }
    // Renormalize weights (guards against dead components).
    let total: f64 = mix.weights.iter().sum();
    for w in mix.weights.iter_mut() {
        *w /= total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Data, DenseMatrix};
    use crate::rng::Rng;
    use crate::tree::middle_out::{self, MiddleOutConfig};

    fn gmm_space(seed: u64) -> (Space, Vec<Vec<f32>>) {
        let mut rng = Rng::new(seed);
        let centers = vec![vec![-20.0f32, 0.0], vec![20.0, 0.0], vec![0.0, 30.0]];
        let mut rows = Vec::new();
        for c in &centers {
            for _ in 0..150 {
                rows.push(vec![
                    c[0] + rng.normal() as f32 * 2.0,
                    c[1] + rng.normal() as f32 * 2.0,
                ]);
            }
        }
        (
            Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows))),
            centers,
        )
    }

    fn seeds_near(centers: &[Vec<f32>], jitter: f32) -> Vec<Vec<f32>> {
        centers
            .iter()
            .map(|c| vec![c[0] + jitter, c[1] - jitter])
            .collect()
    }

    #[test]
    fn naive_em_recovers_centers() {
        let (space, centers) = gmm_space(1);
        let mut mix = Mixture::from_seeds(seeds_near(&centers, 3.0));
        for _ in 0..15 {
            naive_em_step(&space, &mut mix);
        }
        for (m, c) in mix.means.iter().zip(&centers) {
            let d = crate::metrics::dense_euclidean(m, c);
            assert!(d < 1.0, "mean {m:?} far from {c:?}");
        }
        for &v in &mix.variances {
            assert!((1.0..9.0).contains(&v), "variance {v}");
        }
    }

    #[test]
    fn tree_em_exact_mode_matches_naive() {
        let (space, centers) = gmm_space(2);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let mut a = Mixture::from_seeds(seeds_near(&centers, 2.0));
        let mut b = a.clone();
        for _ in 0..5 {
            let la = naive_em_step(&space, &mut a);
            let lb = tree_em_step(&space, &tree, &mut b, 0.0);
            assert!(
                (la - lb).abs() < 1e-6 * (1.0 + la.abs()),
                "loglik {la} vs {lb}"
            );
        }
        for (ma, mb) in a.means.iter().zip(&b.means) {
            for (x, y) in ma.iter().zip(mb) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn tree_em_approx_close_and_cheaper() {
        let (space, centers) = gmm_space(3);
        let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 16, ..Default::default() });
        let mut exact = Mixture::from_seeds(seeds_near(&centers, 2.0));
        let mut approx = exact.clone();
        space.reset_count();
        for _ in 0..5 {
            naive_em_step(&space, &mut exact);
        }
        let naive_d = space.dist_count();
        space.reset_count();
        for _ in 0..5 {
            tree_em_step(&space, &tree, &mut approx, 0.05);
        }
        let tree_d = space.dist_count();
        assert!(tree_d < naive_d, "tree {tree_d} !< naive {naive_d}");
        for (ma, mb) in exact.means.iter().zip(&approx.means) {
            let d = crate::metrics::dense_euclidean(ma, mb);
            assert!(d < 0.5, "approx mean drifted {d}");
        }
    }

    #[test]
    fn loglik_increases() {
        let (space, centers) = gmm_space(4);
        let tree = middle_out::build(&space, &MiddleOutConfig::default());
        let mut mix = Mixture::from_seeds(seeds_near(&centers, 4.0));
        let mut prev = f64::NEG_INFINITY;
        for _ in 0..8 {
            let ll = tree_em_step(&space, &tree, &mut mix, 0.0);
            assert!(ll >= prev - 1e-6 * (1.0 + prev.abs()), "loglik fell: {prev} -> {ll}");
            prev = ll;
        }
    }

    #[test]
    fn weights_sum_to_one() {
        let (space, centers) = gmm_space(5);
        let mut mix = Mixture::from_seeds(seeds_near(&centers, 1.0));
        for _ in 0..5 {
            naive_em_step(&space, &mut mix);
        }
        let total: f64 = mix.weights.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Balanced design → roughly equal weights.
        for &w in &mix.weights {
            assert!((0.2..0.5).contains(&w), "weight {w}");
        }
    }
}
