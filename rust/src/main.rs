//! `anchors-hierarchy` — CLI front-end for the paper reproduction.
//!
//! Commands:
//!   table2 | table3 | table4 | figure1   regenerate the paper's tables/figures
//!   kmeans | anomaly | allpairs | mst    run one algorithm on one dataset
//!   tree                                 build a tree and print its shape
//!   serve-demo                           drive the batch coordinator
//!   artifacts                            inspect the AOT artifact manifest
//!
//! Every command takes `--scale` (fraction of the paper's dataset sizes)
//! and `--seed`; run with no command for usage.

use anchors_hierarchy::algorithms::{allpairs, anomaly, kmeans, mst};
use anchors_hierarchy::bench::tables;
use anchors_hierarchy::cli::Args;
use anchors_hierarchy::coordinator::{Coordinator, JobKind, JobSpec, JobState};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};
use anchors_hierarchy::tree::top_down;
use std::sync::Arc;

const USAGE: &str = "\
anchors-hierarchy — metric trees with cached sufficient statistics
  (reproduction of Moore, 'The Anchors Hierarchy', UAI 2000)

USAGE: anchors-hierarchy <command> [--flag value]...

paper experiments
  table2   [--scale F] [--iters N] [--rmin N] [--datasets a,b,..]  Table 2
  table3   [--scale F] [--iters N] [--rmin N]                      Table 3
  table4   [--scale F] [--iters N] [--rmin N]                      Table 4
  figure1  [--rows N]                                              Figure 1

single runs (common flags: --dataset NAME --scale F --seed N --rmin N
                           --tree BOOL --xla BOOL)
  kmeans   [--k N] [--iters N] [--init random|anchors]
  anomaly  [--threshold N] [--frac F]
  allpairs [--tau F]            (default: auto-calibrated)
  mst
  tree     [--builder middle-out|top-down] [--validate BOOL]

system
  serve-demo [--workers N] [--jobs N]        exercise the coordinator
  serve      [--addr HOST:PORT] [--workers N]  TCP JSON-line job server
  artifacts                                  show the AOT manifest

datasets: squiggles voronoi cell covtype reuters50 reuters100
          gen{100|1000|10000}-k{3|20|100} figure1
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dataset_spec(args: &Args) -> Result<DatasetSpec, String> {
    let name = args.str_flag("dataset", "cell");
    let kind = DatasetKind::parse(&name)
        .ok_or_else(|| format!("unknown dataset {name:?} (see usage)"))?;
    Ok(DatasetSpec {
        kind,
        scale: args.flag("scale", 0.05f64)?,
        seed: args.flag("seed", 20130u64)?,
    })
}

fn maybe_engine(args: &Args) -> Result<Option<Arc<BatchDistanceEngine>>, String> {
    if args.bool_flag("xla", false)? {
        let e = BatchDistanceEngine::open_default()
            .map_err(|e| format!("--xla requested but engine unavailable: {e}"))?;
        Ok(Some(Arc::new(e)))
    } else {
        Ok(None)
    }
}

fn run(args: &Args) -> Result<(), String> {
    match args.command.as_str() {
        "table2" => {
            let mut cfg = tables::Table2Config {
                scale: args.flag("scale", 0.05)?,
                kmeans_iters: args.flag("iters", 5)?,
                rmin: args.flag("rmin", 30)?,
                seed: args.flag("seed", 20130)?,
                datasets: None,
            };
            if let Some(list) = args.opt_str("datasets") {
                let kinds = list
                    .split(',')
                    .map(|n| {
                        DatasetKind::parse(n.trim())
                            .ok_or_else(|| format!("unknown dataset {n:?}"))
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                cfg.datasets = Some(kinds);
            }
            args.finish()?;
            println!(
                "# Table 2 (scale {}, {} k-means iters, rmin {})",
                cfg.scale, cfg.kmeans_iters, cfg.rmin
            );
            let rows = tables::table2(&cfg);
            tables::print_table2(&rows);
            Ok(())
        }
        "table3" => {
            let scale = args.flag("scale", 0.03)?;
            let iters = args.flag("iters", 5)?;
            let rmin = args.flag("rmin", 30)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            println!("# Table 3 (scale {scale}, {iters} iters, rmin {rmin})");
            let rows = tables::table3(scale, iters, rmin, seed);
            tables::print_table3(&rows);
            Ok(())
        }
        "table4" => {
            let scale = args.flag("scale", 0.05)?;
            let iters = args.flag("iters", 50)?;
            let rmin = args.flag("rmin", 30)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            println!("# Table 4 (scale {scale}, {iters} iters, rmin {rmin})");
            let rows = tables::table4(scale, iters, rmin, seed);
            tables::print_table4(&rows);
            Ok(())
        }
        "figure1" => {
            let rows = args.flag("rows", 20_000usize)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            let r = tables::figure1(rows, seed);
            tables::print_figure1(&r);
            Ok(())
        }
        "kmeans" => {
            let spec = dataset_spec(args)?;
            let k = args.flag("k", 20usize)?;
            let iters = args.flag("iters", 10usize)?;
            let rmin = args.flag("rmin", 30usize)?;
            let use_tree = args.bool_flag("tree", true)?;
            let init_name = args.str_flag("init", "random");
            let engine = maybe_engine(args)?;
            args.finish()?;
            let init = match init_name.as_str() {
                "random" => kmeans::Init::Random,
                "anchors" => kmeans::Init::Anchors,
                other => return Err(format!("unknown init {other:?}")),
            };
            let space = spec.build();
            println!(
                "dataset {} ({} rows × {} dims), k={k}, iters={iters}, tree={use_tree}",
                spec.kind.name(),
                space.n(),
                space.dim()
            );
            let opts = kmeans::KmeansOpts { engine, seed: spec.seed, ..Default::default() };
            let result = if use_tree {
                let t0 = std::time::Instant::now();
                let tree = middle_out::build(
                    &space,
                    &MiddleOutConfig { rmin, seed: spec.seed, exact_radii: false },
                );
                println!(
                    "tree: {} nodes, build {} dists, {:.2}s",
                    tree.nodes.len(),
                    tree.build_dists,
                    t0.elapsed().as_secs_f64()
                );
                kmeans::tree_lloyd(&space, &tree, init, k, iters, &opts)
            } else {
                kmeans::naive_lloyd(&space, init, k, iters, &opts)
            };
            println!(
                "distortion {:.6e}  iterations {}  distance computations {}",
                result.distortion, result.iterations, result.dists
            );
            Ok(())
        }
        "anomaly" => {
            let spec = dataset_spec(args)?;
            let threshold = args.flag("threshold", 20u64)?;
            let frac = args.flag("frac", 0.10f64)?;
            let rmin = args.flag("rmin", 30usize)?;
            let use_tree = args.bool_flag("tree", true)?;
            args.finish()?;
            let space = spec.build();
            let radius = anomaly::calibrate_radius(&space, threshold, frac, 50, spec.seed);
            let params = anomaly::AnomalyParams { radius, threshold };
            println!(
                "dataset {} ({} rows), radius {radius:.4}, threshold {threshold}",
                spec.kind.name(),
                space.n()
            );
            let sweep = if use_tree {
                let tree = middle_out::build(
                    &space,
                    &MiddleOutConfig { rmin, seed: spec.seed, exact_radii: false },
                );
                anomaly::tree_sweep(&space, &tree, &params)
            } else {
                anomaly::naive_sweep(&space, &params)
            };
            println!(
                "anomalies {} / {} ({:.1}%), distance computations {}",
                sweep.n_anomalies,
                space.n(),
                100.0 * sweep.n_anomalies as f64 / space.n() as f64,
                sweep.dists
            );
            Ok(())
        }
        "allpairs" => {
            let spec = dataset_spec(args)?;
            let rmin = args.flag("rmin", 30usize)?;
            let use_tree = args.bool_flag("tree", true)?;
            let tau_flag: f64 = args.flag("tau", -1.0)?;
            args.finish()?;
            let space = spec.build();
            let tau = if tau_flag > 0.0 {
                tau_flag
            } else {
                tables::calibrate_tau(&space, spec.seed)
            };
            println!(
                "dataset {} ({} rows), tau {tau:.4}",
                spec.kind.name(),
                space.n()
            );
            let result = if use_tree {
                let tree = middle_out::build(
                    &space,
                    &MiddleOutConfig { rmin, seed: spec.seed, exact_radii: false },
                );
                allpairs::tree_close_pairs(&space, &tree, tau)
            } else {
                allpairs::naive_close_pairs(&space, tau)
            };
            println!(
                "close pairs {}  distance computations {}",
                result.pairs.len(),
                result.dists
            );
            Ok(())
        }
        "mst" => {
            let spec = dataset_spec(args)?;
            let rmin = args.flag("rmin", 30usize)?;
            let use_tree = args.bool_flag("tree", true)?;
            args.finish()?;
            let space = spec.build();
            let edges = if use_tree {
                let tree = middle_out::build(
                    &space,
                    &MiddleOutConfig { rmin, seed: spec.seed, exact_radii: false },
                );
                mst::tree_mst(&space, &tree)
            } else {
                mst::naive_mst(&space)
            };
            println!(
                "MST: {} edges, total weight {:.4}, distance computations {}",
                edges.len(),
                mst::total_weight(&edges),
                space.dist_count()
            );
            Ok(())
        }
        "tree" => {
            let spec = dataset_spec(args)?;
            let rmin = args.flag("rmin", 30usize)?;
            let builder = args.str_flag("builder", "middle-out");
            let validate = args.bool_flag("validate", false)?;
            args.finish()?;
            let space = spec.build();
            let t0 = std::time::Instant::now();
            let tree = match builder.as_str() {
                "middle-out" => middle_out::build(
                    &space,
                    &MiddleOutConfig { rmin, seed: spec.seed, exact_radii: false },
                ),
                "top-down" => top_down::build(&space, rmin),
                other => return Err(format!("unknown builder {other:?}")),
            };
            let shape = tree.shape();
            println!(
                "{} tree over {} ({} rows × {} dims): {} nodes, {} leaves, depth {}, \
                 mean leaf size {:.1}, mean leaf radius {:.4}, build {} dists, {:.2}s",
                builder,
                spec.kind.name(),
                space.n(),
                space.dim(),
                shape.nodes,
                shape.leaves,
                shape.max_depth,
                shape.mean_leaf_size,
                shape.mean_leaf_radius,
                tree.build_dists,
                t0.elapsed().as_secs_f64()
            );
            if validate {
                tree.validate(&space).map_err(|e| format!("INVALID TREE: {e}"))?;
                println!("validation OK");
            }
            Ok(())
        }
        "serve" => {
            let addr = args.str_flag("addr", "127.0.0.1:7407");
            let workers = args.flag("workers", 4usize)?;
            let capacity = args.flag("capacity", 256usize)?;
            args.finish()?;
            let engine = BatchDistanceEngine::open_default().ok().map(Arc::new);
            let coord = Arc::new(Coordinator::with_engine(workers, capacity, engine));
            let server = anchors_hierarchy::coordinator::server::Server::start(&addr, coord)
                .map_err(|e| format!("bind {addr}: {e}"))?;
            println!(
                "serving newline-delimited JSON on {} ({workers} workers, queue {capacity});\nexample: {{\"cmd\":\"submit\",\"dataset\":\"cell\",\"scale\":0.01,\"op\":\"kmeans\",\"k\":10}}\nCtrl-C to stop",
                server.addr()
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "serve-demo" => {
            let workers = args.flag("workers", 4usize)?;
            let jobs = args.flag("jobs", 12usize)?;
            let scale = args.flag("scale", 0.01f64)?;
            let seed = args.flag("seed", 20130u64)?;
            args.finish()?;
            serve_demo(workers, jobs, scale, seed)
        }
        "artifacts" => {
            args.finish()?;
            let engine = BatchDistanceEngine::open_default()
                .map_err(|e| format!("{e} (run `make artifacts`)"))?;
            let m = engine.manifest();
            println!("tiles: n={} k={}", m.tile_n, m.tile_k);
            for program in m.programs() {
                println!("  {program}: widths {:?}", m.widths(program));
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    }
}

/// Drive the coordinator with a mixed batch of jobs across datasets.
fn serve_demo(workers: usize, jobs: usize, scale: f64, seed: u64) -> Result<(), String> {
    println!("coordinator: {workers} workers, submitting {jobs} jobs (scale {scale})");
    let engine = BatchDistanceEngine::open_default().ok().map(Arc::new);
    if engine.is_some() {
        println!("XLA batch engine: enabled");
    }
    let coord = Coordinator::with_engine(workers, jobs * 2, engine);
    let datasets = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
        DatasetKind::Covtype,
    ];
    let mut ids = Vec::new();
    for i in 0..jobs {
        let dataset = DatasetSpec { kind: datasets[i % datasets.len()].clone(), scale, seed };
        let kind = match i % 3 {
            0 => JobKind::Kmeans { k: 10, iters: 5, anchors_init: i % 2 == 0 },
            1 => JobKind::Anomaly { threshold: 10, target_frac: 0.1 },
            _ => JobKind::AllPairs { tau: 0.5 },
        };
        let spec = JobSpec { dataset, kind, use_tree: true, rmin: 30 };
        match coord.submit(spec) {
            Ok(id) => ids.push(id),
            Err(e) => println!("job {i} rejected: {e:?}"),
        }
    }
    for id in ids {
        match coord.wait(id) {
            JobState::Done(r) => println!(
                "job {id}: {:?}  dists {}  wall {:.1} ms",
                r.output, r.dists, r.wall_ms
            ),
            JobState::Failed(e) => println!("job {id} FAILED: {e}"),
            _ => unreachable!(),
        }
    }
    let m = coord.shutdown();
    println!(
        "done: submitted {} completed {} failed {} rejected {} total-dists {}",
        m.submitted, m.completed, m.failed, m.rejected, m.total_dists
    );
    Ok(())
}
