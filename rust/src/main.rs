//! `anchors-hierarchy` — CLI front-end for the paper reproduction.
//!
//! Commands:
//!   table2 | table3 | table4 | figure1   regenerate the paper's tables/figures
//!   kmeans | xmeans | anomaly | allpairs |
//!   ball | ballstats | kde | kreg |
//!   em | knn | mst                       run one engine query on one dataset
//!   tree                                 build a tree and print its shape
//!   serve-demo                           drive the batch coordinator
//!   serve                                TCP JSON-line job server
//!   stats                                query a running server's obs snapshot
//!   artifacts                            inspect the AOT artifact manifest
//!
//! Every single-run command is a thin wrapper over the engine facade:
//! flags build an [`engine::Query`], an [`engine::IndexBuilder`] stands
//! up the index, and `Index::run_traced` executes it; the shared
//! [`obs::format_run_report`] formatter prints distance accounting plus
//! the traversal counters (nodes visited, prunes by rule, leaf rows,
//! frontier peak, per-level fan-out). Run with no command for usage.

use anchors_hierarchy::bench::tables;
use anchors_hierarchy::cli::Args;
use anchors_hierarchy::coordinator::{shard, JobSpec, JobState, ShardedCoordinator};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::algorithms::kde::Kernel;
use anchors_hierarchy::engine::{
    AllPairsQuery, AnomalyQuery, BallQuery, BallStatsQuery, GaussianEmQuery, Index, IndexBuilder,
    InitKind, KdeQuery, KernelRegressionQuery, KmeansQuery, KnnQuery, KnnTarget, MstQuery, Query,
    TreeStrategy, XmeansQuery,
};
use anchors_hierarchy::json::Value;
use anchors_hierarchy::obs;
use anchors_hierarchy::parallel::Parallelism;
use anchors_hierarchy::runtime::BatchDistanceEngine;
use std::sync::Arc;

const USAGE: &str = "\
anchors-hierarchy — metric trees with cached sufficient statistics
  (reproduction of Moore, 'The Anchors Hierarchy', UAI 2000)

USAGE: anchors-hierarchy <command> [--flag value]...

paper experiments
  table2   [--scale F] [--iters N] [--rmin N] [--datasets a,b,..]  Table 2
  table3   [--scale F] [--iters N] [--rmin N]                      Table 3
  table4   [--scale F] [--iters N] [--rmin N]                      Table 4
  figure1  [--rows N]                                              Figure 1

engine queries (common flags: --dataset NAME --scale F --seed N --rmin N
                              --tree BOOL --builder middle-out|top-down
                              --xla BOOL --threads auto|serial|N
                              --f32 BOOL   exact f32 filter tier; default
                                           $PALLAS_F32_TIER, else off)
  kmeans   [--k N] [--iters N] [--init random|anchors]
  xmeans   [--kmin N] [--kmax N]
  anomaly  [--threshold N] [--frac F] [--radius F]
  allpairs [--tau F]            (default: auto-calibrated)
  ball     [--radius F]         (ball at the dataset mean)
  ballstats [--radius F]        (exact count/mean/per-dim variance in a ball)
  kde      [--bandwidth F] [--kernel gaussian|epanechnikov]
           [--epsabs F] [--epsrel F]       bounded-error kernel density
  kreg     [--target N] [--bandwidth F] [--kernel gaussian|epanechnikov]
           [--epsabs F] [--epsrel F]       bounded-error kernel regression
  em       [--k N] [--steps N] [--tau F] [--init random|anchors]
  knn      [--point N] [--k N]
  mst
  tree     [--validate BOOL]    build only; print the tree's shape

system
  serve-demo [--workers N] [--jobs N] [--shards N]  exercise the coordinator
  serve      [--addr HOST:PORT] [--workers N] [--shards N] [--capacity N]
             [--deadline-ms N] [--max-conns N]
             TCP JSON-line job server; --shards N = independent
             coordinator shards (consistent-hash dataset routing),
             --workers per shard. Default shards: $PALLAS_SHARDS, else 1.
             --deadline-ms N = default job deadline for submits that
             carry none (0 = off); --max-conns = connection cap.
             Exits 0 after a client-issued {\"cmd\":\"drain\"}
  drain      [--addr HOST:PORT] [--timeout-ms N]
             drain a running server: stop intake, wait (bounded) for
             in-flight jobs, report stragglers; the server then exits
  stats      [--addr HOST:PORT] [--format prom|json]
             fetch a running server's observability snapshot (latency
             histograms + per-family traversal counters); prom prints
             the Prometheus text exposition, json the raw response
  artifacts                                  show the AOT manifest

datasets: squiggles voronoi cell covtype reuters50 reuters100
          gen{100|1000|10000}-k{3|20|100} figure1
";

fn main() {
    // Deterministic fault drills: $PALLAS_FAULTS (default off). A set
    // but unparsable spec is a loud exit, not a silently skipped drill.
    match anchors_hierarchy::faults::from_env() {
        Ok(plan) => {
            if let Some(p) = &plan {
                eprintln!("fault drill active: $PALLAS_FAULTS seed {}", p.seed);
            }
            anchors_hierarchy::faults::install(plan);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dataset_spec(args: &Args) -> Result<DatasetSpec, String> {
    let name = args.str_flag("dataset", "cell");
    let kind = DatasetKind::parse(&name)
        .ok_or_else(|| format!("unknown dataset {name:?} (see usage)"))?;
    Ok(DatasetSpec {
        kind,
        scale: args.flag("scale", 0.05f64)?,
        seed: args.flag("seed", 20130u64)?,
    })
}

fn maybe_engine(args: &Args) -> Result<Option<Arc<BatchDistanceEngine>>, String> {
    if args.bool_flag("xla", false)? {
        let e = BatchDistanceEngine::open_default()
            .map_err(|e| format!("--xla requested but engine unavailable: {e}"))?;
        Ok(Some(Arc::new(e)))
    } else {
        Ok(None)
    }
}

/// Shared flag handling for the engine-query commands: build the index
/// from `--dataset/--scale/--seed/--rmin/--builder/--xla`.
fn build_index(args: &Args) -> Result<(DatasetSpec, Index), String> {
    let spec = dataset_spec(args)?;
    let rmin = args.flag("rmin", 30usize)?;
    let builder_name = args.str_flag("builder", "middle-out");
    let strategy = TreeStrategy::parse(&builder_name)
        .ok_or_else(|| format!("unknown builder {builder_name:?}"))?;
    let engine = maybe_engine(args)?;
    let parallelism = match args.opt_str("threads") {
        None => Parallelism::default(), // $PALLAS_THREADS, else auto
        Some(raw) => Parallelism::parse(&raw)
            .ok_or_else(|| format!("--threads: expected auto|serial|N, found {raw:?}"))?,
    };
    let mut builder = IndexBuilder::new(spec.clone())
        .rmin(rmin)
        .strategy(strategy)
        .batch_engine(engine)
        .parallelism(parallelism);
    // --f32 wins over the $PALLAS_F32_TIER default; absent, the env
    // default applied inside DatasetSpec::build governs.
    if args.opt_str("f32").is_some() {
        builder = builder.with_f32_tier(args.bool_flag("f32", false)?);
    }
    let index = builder.build();
    println!(
        "dataset {} ({} rows × {} dims)",
        spec.kind.name(),
        index.space().n(),
        index.space().dim()
    );
    Ok((spec, index))
}

/// Execute one query against a fresh index and report the result, the
/// engine's exact distance accounting, and the traversal counters —
/// all through the one shared [`obs::format_run_report`] formatter.
fn run_query(args: &Args, index: &Index, query: Query) -> Result<(), String> {
    args.finish()?;
    let before = index.dist_count();
    let before_f32 = index.f32_dist_count();
    let t0 = std::time::Instant::now();
    let (result, stats) = index.run_traced(&query);
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", result.summary());
    print!(
        "{}",
        obs::format_run_report(
            index.dist_count() - before,
            index.f32_dist_count() - before_f32,
            &stats,
            Some(wall),
        )
    );
    Ok(())
}

fn run(args: &Args) -> Result<(), String> {
    match args.command.as_str() {
        "table2" => {
            let mut cfg = tables::Table2Config {
                scale: args.flag("scale", 0.05)?,
                kmeans_iters: args.flag("iters", 5)?,
                rmin: args.flag("rmin", 30)?,
                seed: args.flag("seed", 20130)?,
                datasets: None,
            };
            if let Some(list) = args.opt_str("datasets") {
                let kinds = list
                    .split(',')
                    .map(|n| {
                        DatasetKind::parse(n.trim())
                            .ok_or_else(|| format!("unknown dataset {n:?}"))
                    })
                    .collect::<Result<Vec<_>, _>>()?;
                cfg.datasets = Some(kinds);
            }
            args.finish()?;
            println!(
                "# Table 2 (scale {}, {} k-means iters, rmin {})",
                cfg.scale, cfg.kmeans_iters, cfg.rmin
            );
            let rows = tables::table2(&cfg);
            tables::print_table2(&rows);
            Ok(())
        }
        "table3" => {
            let scale = args.flag("scale", 0.03)?;
            let iters = args.flag("iters", 5)?;
            let rmin = args.flag("rmin", 30)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            println!("# Table 3 (scale {scale}, {iters} iters, rmin {rmin})");
            let rows = tables::table3(scale, iters, rmin, seed);
            tables::print_table3(&rows);
            Ok(())
        }
        "table4" => {
            let scale = args.flag("scale", 0.05)?;
            let iters = args.flag("iters", 50)?;
            let rmin = args.flag("rmin", 30)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            println!("# Table 4 (scale {scale}, {iters} iters, rmin {rmin})");
            let rows = tables::table4(scale, iters, rmin, seed);
            tables::print_table4(&rows);
            Ok(())
        }
        "figure1" => {
            let rows = args.flag("rows", 20_000usize)?;
            let seed = args.flag("seed", 20130)?;
            args.finish()?;
            let r = tables::figure1(rows, seed);
            tables::print_figure1(&r);
            Ok(())
        }
        "kmeans" => {
            let (_, index) = build_index(args)?;
            let init_name = args.str_flag("init", "random");
            let init = InitKind::parse(&init_name)
                .ok_or_else(|| format!("unknown init {init_name:?}"))?;
            let query = Query::Kmeans(KmeansQuery {
                k: args.flag("k", 20usize)?,
                iters: args.flag("iters", 10usize)?,
                init,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "xmeans" => {
            let (_, index) = build_index(args)?;
            let query = Query::Xmeans(XmeansQuery {
                k_min: args.flag("kmin", 1usize)?,
                k_max: args.flag("kmax", 16usize)?,
            });
            run_query(args, &index, query)
        }
        "anomaly" => {
            let (_, index) = build_index(args)?;
            let radius: f64 = args.flag("radius", -1.0)?;
            let query = Query::Anomaly(AnomalyQuery {
                threshold: args.flag("threshold", 20u64)?,
                radius: (radius > 0.0).then_some(radius),
                target_frac: args.flag("frac", 0.10f64)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "allpairs" => {
            let (spec, index) = build_index(args)?;
            let tau_flag: f64 = args.flag("tau", -1.0)?;
            let tau = if tau_flag > 0.0 {
                tau_flag
            } else {
                tables::calibrate_tau(index.space(), spec.seed)
            };
            println!("tau {tau:.4}");
            let query =
                Query::AllPairs(AllPairsQuery { tau, use_tree: args.bool_flag("tree", true)? });
            run_query(args, &index, query)
        }
        "ball" => {
            let (_, index) = build_index(args)?;
            // Center at the dataset mean — the §1 "query some quantity
            // over some subset of the records" demo.
            let all: Vec<u32> = (0..index.space().n() as u32).collect();
            let center = index.space().centroid(&all);
            let query = Query::Ball(BallQuery {
                center,
                radius: args.flag("radius", 1.0f64)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "ballstats" => {
            let (_, index) = build_index(args)?;
            let all: Vec<u32> = (0..index.space().n() as u32).collect();
            let center = index.space().centroid(&all);
            let query = Query::BallStats(BallStatsQuery {
                center,
                radius: args.flag("radius", 1.0f64)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "kde" => {
            let (_, index) = build_index(args)?;
            let all: Vec<u32> = (0..index.space().n() as u32).collect();
            let center = index.space().centroid(&all);
            let kernel_name = args.str_flag("kernel", "gaussian");
            let kernel = Kernel::parse(&kernel_name)
                .ok_or_else(|| format!("unknown kernel {kernel_name:?}"))?;
            let query = Query::Kde(KdeQuery {
                center,
                kernel,
                bandwidth: args.flag("bandwidth", 1.0f64)?,
                eps_abs: args.flag("epsabs", 0.0f64)?,
                eps_rel: args.flag("epsrel", 0.01f64)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "kreg" => {
            let (_, index) = build_index(args)?;
            let all: Vec<u32> = (0..index.space().n() as u32).collect();
            let center = index.space().centroid(&all);
            let kernel_name = args.str_flag("kernel", "gaussian");
            let kernel = Kernel::parse(&kernel_name)
                .ok_or_else(|| format!("unknown kernel {kernel_name:?}"))?;
            let query = Query::KernelRegression(KernelRegressionQuery {
                center,
                target_dim: args.flag("target", 0usize)?,
                kernel,
                bandwidth: args.flag("bandwidth", 1.0f64)?,
                eps_abs: args.flag("epsabs", 0.0f64)?,
                eps_rel: args.flag("epsrel", 0.01f64)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "em" => {
            let (_, index) = build_index(args)?;
            let init_name = args.str_flag("init", "random");
            let init = InitKind::parse(&init_name)
                .ok_or_else(|| format!("unknown init {init_name:?}"))?;
            let query = Query::GaussianEm(GaussianEmQuery {
                k: args.flag("k", 5usize)?,
                steps: args.flag("steps", 5usize)?,
                tau: args.flag("tau", 0.0f64)?,
                init,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "knn" => {
            let (_, index) = build_index(args)?;
            let query = Query::Knn(KnnQuery {
                target: KnnTarget::Point(args.flag("point", 0u32)?),
                k: args.flag("k", 5usize)?,
                use_tree: args.bool_flag("tree", true)?,
            });
            run_query(args, &index, query)
        }
        "mst" => {
            let (_, index) = build_index(args)?;
            let query = Query::Mst(MstQuery { use_tree: args.bool_flag("tree", true)? });
            run_query(args, &index, query)
        }
        "tree" => {
            let (_, index) = build_index(args)?;
            let validate = args.bool_flag("validate", false)?;
            args.finish()?;
            let t0 = std::time::Instant::now();
            let tree = index.tree();
            let shape = tree.shape();
            println!(
                "{} nodes, {} leaves, depth {}, mean leaf size {:.1}, \
                 mean leaf radius {:.4}, build {} dists, {:.2}s",
                shape.nodes,
                shape.leaves,
                shape.max_depth,
                shape.mean_leaf_size,
                shape.mean_leaf_radius,
                tree.build_dists,
                t0.elapsed().as_secs_f64()
            );
            if validate {
                tree.validate(index.space())
                    .map_err(|e| format!("INVALID TREE: {e}"))?;
                println!("validation OK");
            }
            Ok(())
        }
        "serve" => {
            let addr = args.str_flag("addr", "127.0.0.1:7407");
            let workers = args.flag("workers", 4usize)?;
            let capacity = args.flag("capacity", 256usize)?;
            // --shards wins; else $PALLAS_SHARDS (shard::default_shards
            // is its single owner — a set-but-invalid value errors
            // loudly even when the flag is given); else 1. Out-of-range
            // values are clamped by the constructor.
            let shards = args.flag("shards", shard::default_shards()?)?;
            // Default job deadline for submits that carry none; 0 = off.
            let deadline_ms = args.flag("deadline-ms", 0u64)?;
            let max_conns = args.flag("max-conns", 256usize)?;
            args.finish()?;
            let engine = BatchDistanceEngine::open_default().ok().map(Arc::new);
            let coord = Arc::new(ShardedCoordinator::with_engine(
                shards, workers, capacity, engine,
            ));
            let shards = coord.n_shards();
            let opts = anchors_hierarchy::coordinator::server::ServerOptions {
                max_conns,
                default_deadline_ms: (deadline_ms > 0).then_some(deadline_ms),
                ..Default::default()
            };
            let server =
                anchors_hierarchy::coordinator::server::Server::start_with(&addr, coord, opts)
                    .map_err(|e| format!("bind {addr}: {e}"))?;
            println!(
                "serving newline-delimited JSON on {} ({shards} shard(s) × {workers} workers, queue {capacity} each);\nexample: {{\"cmd\":\"submit\",\"dataset\":\"cell\",\"scale\":0.01,\"op\":\"kmeans\",\"k\":10}}\nCtrl-C to stop, {{\"cmd\":\"drain\"}} to shut down cleanly",
                server.addr()
            );
            loop {
                // pallas-lint: allow(threads, CLI serve loop parks the foreground thread; not a result-producing path)
                std::thread::sleep(std::time::Duration::from_millis(500));
                if server.draining() {
                    // The drain op already waited for the coordinator:
                    // every accepted job is terminal. A short grace lets
                    // in-flight responses flush, then exit cleanly.
                    println!("drain requested; shutting down");
                    // pallas-lint: allow(threads, drain grace period before a clean exit; not a result-producing path)
                    std::thread::sleep(std::time::Duration::from_secs(2));
                    return Ok(());
                }
            }
        }
        "drain" => {
            let addr = args.str_flag("addr", "127.0.0.1:7407");
            let timeout_ms = args.flag("timeout-ms", 60_000u64)?;
            args.finish()?;
            let mut client = anchors_hierarchy::coordinator::server::Client::connect(&*addr)
                .map_err(|e| format!("connect {addr}: {e}"))?;
            let req = anchors_hierarchy::coordinator::server::Client::request(vec![
                ("cmd", Value::Str("drain".into())),
                ("timeout_ms", Value::Num(anchors_hierarchy::ids::wire_from_u64(timeout_ms))),
            ]);
            let resp = client.call(&req)?;
            if resp.get("ok") != Some(&Value::Bool(true)) {
                return Err(format!("server error: {}", anchors_hierarchy::json::write(&resp)));
            }
            println!("{}", anchors_hierarchy::json::write(&resp));
            if resp.get("drained") == Some(&Value::Bool(true)) {
                Ok(())
            } else {
                Err("drain timed out with stragglers still running".into())
            }
        }
        "stats" => {
            let addr = args.str_flag("addr", "127.0.0.1:7407");
            let format = args.str_flag("format", "prom");
            args.finish()?;
            let mut client = anchors_hierarchy::coordinator::server::Client::connect(&*addr)
                .map_err(|e| format!("connect {addr}: {e}"))?;
            let req = anchors_hierarchy::coordinator::server::Client::request(vec![(
                "cmd",
                Value::Str("stats".into()),
            )]);
            let resp = client.call(&req)?;
            if resp.get("ok") != Some(&Value::Bool(true)) {
                return Err(format!("server error: {}", anchors_hierarchy::json::write(&resp)));
            }
            match format.as_str() {
                "prom" => {
                    let text = resp
                        .get("text")
                        .and_then(Value::as_str)
                        .ok_or("response missing text exposition")?;
                    print!("{text}");
                }
                "json" => println!("{}", anchors_hierarchy::json::write(&resp)),
                other => return Err(format!("--format: expected prom|json, found {other:?}")),
            }
            Ok(())
        }
        "serve-demo" => {
            let workers = args.flag("workers", 4usize)?;
            let jobs = args.flag("jobs", 12usize)?;
            let scale = args.flag("scale", 0.01f64)?;
            let seed = args.flag("seed", 20130u64)?;
            let shards = args.flag("shards", shard::default_shards()?)?;
            args.finish()?;
            serve_demo(shards, workers, jobs, scale, seed)
        }
        "artifacts" => {
            args.finish()?;
            let engine = BatchDistanceEngine::open_default()
                .map_err(|e| format!("{e} (run `make artifacts`)"))?;
            let m = engine.manifest();
            println!("tiles: n={} k={}", m.tile_n, m.tile_k);
            for program in m.programs() {
                println!("  {program}: widths {:?}", m.widths(program));
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    }
}

/// Drive the coordinator with a mixed batch of engine queries across
/// datasets — every query family in rotation.
fn serve_demo(
    shards: usize,
    workers: usize,
    jobs: usize,
    scale: f64,
    seed: u64,
) -> Result<(), String> {
    let engine = BatchDistanceEngine::open_default().ok().map(Arc::new);
    if engine.is_some() {
        println!("XLA batch engine: enabled");
    }
    let coord = ShardedCoordinator::with_engine(shards, workers, jobs * 2, engine);
    // Report the clamped count the coordinator actually runs with, not
    // the requested one.
    let shards = coord.n_shards();
    println!(
        "coordinator: {shards} shard(s) × {workers} workers, submitting {jobs} jobs (scale {scale})"
    );
    let datasets = [
        DatasetKind::Squiggles,
        DatasetKind::Voronoi,
        DatasetKind::Cell,
        DatasetKind::Covtype,
    ];
    let mut ids = Vec::new();
    for i in 0..jobs {
        let dataset = DatasetSpec { kind: datasets[i % datasets.len()].clone(), scale, seed };
        let query = match i % 5 {
            0 => Query::Kmeans(KmeansQuery {
                k: 10,
                iters: 5,
                init: if i % 2 == 0 { InitKind::Anchors } else { InitKind::Random },
                use_tree: true,
            }),
            1 => Query::Anomaly(AnomalyQuery { threshold: 10, ..Default::default() }),
            2 => Query::AllPairs(AllPairsQuery { tau: 0.5, use_tree: true }),
            3 => Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 5, use_tree: true }),
            _ => Query::Mst(MstQuery { use_tree: true }),
        };
        let spec = JobSpec { dataset, query, rmin: 30, deadline_ms: None };
        match coord.submit(spec) {
            Ok(id) => ids.push(id),
            Err(e) => println!("job {i} rejected: {e:?}"),
        }
    }
    for id in ids {
        match coord.wait(id) {
            JobState::Done(r) => println!(
                "job {id}: {}  dists {}  wall {:.1} ms",
                r.output.summary(),
                r.dists,
                r.wall_ms
            ),
            JobState::Failed(e) => println!("job {id} FAILED: {e}"),
            _ => unreachable!(),
        }
    }
    for (shard, m) in coord.shard_metrics().iter().enumerate() {
        println!(
            "shard {shard}: submitted {} completed {} failed {} dists {}",
            m.submitted, m.completed, m.failed, m.total_dists
        );
    }
    let m = coord.shutdown();
    println!(
        "done: submitted {} completed {} failed {} rejected {} cancelled {}+{} deadline {} breaker {} total-dists {}",
        m.submitted,
        m.completed,
        m.failed,
        m.rejected,
        m.cancelled,
        m.cancelled_running,
        m.deadline_exceeded,
        m.breaker_open,
        m.total_dists
    );
    Ok(())
}
