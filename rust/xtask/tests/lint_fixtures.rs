//! Fixture-driven self-tests for pallas-lint: one violating and one
//! clean fixture per rule D1–D6, exact `(line, rule)` diagnostics, the
//! allow-without-reason error, and the "final tree is clean" gate.
//!
//! Fixtures live in `tests/fixtures/` and are linted under a *virtual*
//! path chosen to land in the right rule scope (rule scopes are
//! path-based), so they never trip the real repo scan.

use std::path::Path;
use xtask::lint::{lint_source, Report};

/// Virtual path inside the D1–D4 scopes (algorithms/).
const ALGO: &str = "rust/src/algorithms/fixture.rs";
/// Virtual path inside the D5/D6 scopes (wire files).
const WIRE: &str = "rust/src/engine/wire.rs";
/// Virtual path inside the D5 directory scope (fault injection).
const FAULTS: &str = "rust/src/faults/fixture.rs";

fn lint_fixture(name: &str, virtual_path: &str) -> Report {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    lint_source(virtual_path, &src)
}

/// Assert the exact `(line, rule)` multiset of a report, in order, and
/// that each message names the offending token.
fn assert_diags(report: &Report, expected: &[(usize, &str, &str)]) {
    let got: Vec<(usize, &str)> = report
        .diagnostics
        .iter()
        .map(|d| (d.line, d.rule))
        .collect();
    let want: Vec<(usize, &str)> = expected.iter().map(|&(l, r, _)| (l, r)).collect();
    assert_eq!(got, want, "diagnostics: {:#?}", report.diagnostics);
    for (d, &(_, _, token)) in report.diagnostics.iter().zip(expected) {
        assert!(
            d.msg.contains(token),
            "message {:?} does not name the token {token:?}",
            d.msg
        );
    }
}

#[test]
fn d1_hash_order() {
    let v = lint_fixture("d1_hash_order_violate.rs", ALGO);
    assert_diags(&v, &[(5, "hash-order", "HashMap")]);
    let c = lint_fixture("d1_hash_order_clean.rs", ALGO);
    assert_diags(&c, &[]);
}

#[test]
fn d2_wall_clock() {
    let v = lint_fixture("d2_wall_clock_violate.rs", ALGO);
    assert_diags(
        &v,
        &[(5, "wall-clock", "Instant"), (7, "wall-clock", "elapsed")],
    );
    let c = lint_fixture("d2_wall_clock_clean.rs", ALGO);
    assert_diags(&c, &[]);
}

#[test]
fn d2_scope_obs_and_serving_edge() {
    // One fixture, five virtual paths: the D2 scope itself is under
    // test. Timing code is legal in obs/ and at the serving edge…
    for home in [
        "rust/src/obs/fixture.rs",
        "rust/src/coordinator/fixture.rs",
        "rust/src/main.rs",
    ] {
        let r = lint_fixture("d2_obs_edge_clean.rs", home);
        assert_diags(&r, &[]);
        assert_eq!(r.suppressed, 0, "no allow needed at {home}");
    }
    // …and a violation in pure-algorithm code, engine/ included:
    // `run_traced` returns deterministic counters, never timings.
    for denied in [ALGO, "rust/src/engine/fixture.rs"] {
        let r = lint_fixture("d2_obs_edge_clean.rs", denied);
        assert_diags(
            &r,
            &[(8, "wall-clock", "Instant"), (10, "wall-clock", "elapsed")],
        );
    }
}

#[test]
fn d3_uncounted_dist() {
    let v = lint_fixture("d3_uncounted_dist_violate.rs", ALGO);
    assert_diags(&v, &[(5, "uncounted-dist", "dense_dot")]);
    // The clean fixture makes the same call but counts it and carries a
    // reasoned allow: zero diagnostics, exactly one suppression.
    let c = lint_fixture("d3_uncounted_dist_clean.rs", ALGO);
    assert_diags(&c, &[]);
    assert_eq!(c.suppressed, 1);
}

#[test]
fn d3_f32_tier_tokens() {
    // The f32 tier's raw kernels get their own tokens: token matching is
    // identifier-exact, so `dense_dot` does NOT cover `dense_dot_f32`.
    let v = lint_fixture("d3_f32_tier_violate.rs", ALGO);
    assert_diags(
        &v,
        &[
            (6, "uncounted-dist", "rows_slab_f32"),
            (7, "uncounted-dist", "dot_vec_f32"),
            (8, "uncounted-dist", "dense_dot_f32"),
        ],
    );
    // Routing through block::dists_contig_to_vec_f32 (which counts both
    // cells itself) is clean with no allow needed.
    let c = lint_fixture("d3_f32_tier_clean.rs", ALGO);
    assert_diags(&c, &[]);
    assert_eq!(c.suppressed, 0);
}

#[test]
fn d4_threads() {
    let v = lint_fixture("d4_threads_violate.rs", ALGO);
    // `std::thread::spawn` trips both thread tokens on the same line.
    assert_diags(
        &v,
        &[(5, "threads", "std::thread"), (5, "threads", "thread::spawn")],
    );
    let c = lint_fixture("d4_threads_clean.rs", ALGO);
    assert_diags(&c, &[]);
}

#[test]
fn d5_panic_wire() {
    let v = lint_fixture("d5_panic_wire_violate.rs", WIRE);
    assert_diags(
        &v,
        &[
            (4, "panic-wire", "[<int>] indexing"),
            (5, "panic-wire", ".unwrap()"),
        ],
    );
    let c = lint_fixture("d5_panic_wire_clean.rs", WIRE);
    assert_diags(&c, &[]);
}

#[test]
fn d5_panic_wire_covers_faults_dir_and_shard_router() {
    let v = lint_fixture("d5_faults_dir_violate.rs", FAULTS);
    assert_diags(
        &v,
        &[
            (4, "panic-wire", ".unwrap()"),
            (6, "panic-wire", "unreachable!"),
            (8, "panic-wire", "[<int>] indexing"),
        ],
    );
    let c = lint_fixture("d5_faults_dir_clean.rs", FAULTS);
    assert_diags(&c, &[]);
    // The sharded router sits on the request path, so the same source
    // fires under its path too...
    let s = lint_fixture("d5_faults_dir_violate.rs", "rust/src/coordinator/shard.rs");
    assert_eq!(s.diagnostics.len(), 3, "{:#?}", s.diagnostics);
    assert!(s.diagnostics.iter().all(|d| d.rule == "panic-wire"));
    // ...while a non-wire coordinator path stays out of D5 scope.
    let out = lint_fixture("d5_faults_dir_violate.rs", "rust/src/coordinator/mod.rs");
    assert_diags(&out, &[]);
}

#[test]
fn d6_lossy_cast() {
    let v = lint_fixture("d6_lossy_cast_violate.rs", WIRE);
    assert_diags(&v, &[(4, "lossy-cast", "as u64")]);
    let c = lint_fixture("d6_lossy_cast_clean.rs", WIRE);
    assert_diags(&c, &[]);
}

#[test]
fn allow_without_reason_is_an_error() {
    let r = lint_fixture("bad_allow_no_reason.rs", ALGO);
    // The malformed directive is reported AND the violation it failed
    // to suppress still fires.
    assert_eq!(r.diagnostics.len(), 2, "{:#?}", r.diagnostics);
    assert_eq!(
        (r.diagnostics[0].line, r.diagnostics[0].rule),
        (4, "bad-allow")
    );
    assert_eq!(
        (r.diagnostics[1].line, r.diagnostics[1].rule),
        (5, "uncounted-dist")
    );
    assert_eq!(r.suppressed, 0);
}

#[test]
fn diagnostics_render_file_line_rule() {
    let v = lint_fixture("d6_lossy_cast_violate.rs", WIRE);
    let rendered = v.diagnostics[0].to_string();
    assert!(
        rendered.starts_with("rust/src/engine/wire.rs:4: [lossy-cast] "),
        "unexpected rendering: {rendered}"
    );
}

#[test]
fn fixtures_never_leak_into_scope() {
    // A fixture linted under the xtask tree itself is out of every
    // scope: the path gate, not luck, keeps self-tests out of the scan.
    let r = lint_fixture(
        "d1_hash_order_violate.rs",
        "rust/xtask/tests/fixtures/d1_hash_order_violate.rs",
    );
    assert_diags(&r, &[]);
}

#[test]
fn repo_tree_is_lint_clean() {
    // The acceptance gate: the shipped tree has zero violations. This
    // is the same walk `cargo run -p xtask -- lint` performs in CI.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../..")
        .canonicalize()
        .expect("repo root");
    assert_eq!(xtask::lint::run(&root), 0, "repo tree has lint violations");
}
