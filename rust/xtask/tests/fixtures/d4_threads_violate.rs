//! D4 fixture: ad-hoc thread spawn outside parallel/ and coordinator/.

pub fn fan_out(jobs: usize) {
    for _ in 0..jobs {
        std::thread::spawn(|| {});
    }
}
