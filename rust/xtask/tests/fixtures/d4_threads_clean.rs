//! D4 fixture (clean): parallelism goes through the shared executor.
use crate::parallel::Executor;

pub fn fan_out(exec: &Executor, jobs: usize) -> Vec<u64> {
    exec.map_chunks(jobs, 1, |range| range.map(|j| j as u64).collect())
        .flatten()
        .collect()
}
