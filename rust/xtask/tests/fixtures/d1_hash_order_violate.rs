//! D1 fixture: HashMap iteration in a result-producing path.
use std::collections::HashMap;

pub fn merge(xs: &[(u32, f64)]) -> Vec<(u32, f64)> {
    let mut m = HashMap::new();
    for &(k, v) in xs {
        m.insert(k, v);
    }
    m.into_iter().collect()
}
