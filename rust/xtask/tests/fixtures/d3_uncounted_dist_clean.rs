//! D3 fixture (clean): the same primitive, explicitly counted and
//! suppressed with a reasoned allow.
use crate::metrics::{dense_dot, Space};

pub fn sim(space: &Space, a: &[f32], b: &[f32]) -> f64 {
    space.count_bulk(1);
    // pallas-lint: allow(uncounted-dist, counted via count_bulk on the previous line)
    dense_dot(a, b)
}
