//! D6 fixture: a raw `as` cast saturates garbage ids silently.

pub fn decode_id(raw: f64) -> u64 {
    raw as u64
}
