//! Fixture: an allow directive without a reason is itself an error.

pub fn sim(a: &[f32], b: &[f32]) -> f64 {
    // pallas-lint: allow(uncounted-dist)
    dense_dot(a, b)
}
