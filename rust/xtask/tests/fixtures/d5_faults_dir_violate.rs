//! D5 fixture: panics inside the fault-injection directory scope.

pub fn plan_rate(plan: &Plan) -> u32 {
    let slot = LOCK.lock().unwrap();
    if slot.is_none() {
        unreachable!("drill installed");
    }
    plan.rates[0]
}
