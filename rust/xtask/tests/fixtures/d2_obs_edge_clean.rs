//! D2 scope fixture: serving-edge timing. The *same source* is clean
//! when linted under `obs/`, `coordinator/` or `main.rs` (the sanctioned
//! homes for clocks) and a violation under `algorithms/` or `engine/` —
//! the path gate, not the code, decides.
use std::time::Instant;

pub fn record_latency(hist: &mut Vec<u64>) {
    let start = Instant::now();
    serve_one();
    let micros = u64::try_from(start.elapsed().as_micros()).unwrap_or(u64::MAX);
    hist.push(micros);
}

fn serve_one() {}
