//! D3 fixture: raw f32 filter-tier kernels outside the counted block
//! helper. Each body line trips one widened `uncounted-dist` token.
use crate::metrics::dense_dot_f32;

pub fn prune(d: &crate::data::Data, q: &[f32]) -> f32 {
    let (slab, _norms) = d.rows_slab_f32(0..4);
    let sparse = d.dot_vec_f32(0, q);
    sparse + dense_dot_f32(&slab[..q.len()], q)
}
