//! D5 fixture (clean): slice patterns and errors instead of panics.

pub fn first_field(p: &[Value]) -> Result<f64, String> {
    match p {
        [head, ..] => head.as_f64().ok_or_else(|| "not a number".to_string()),
        [] => Err("empty".to_string()),
    }
}
