//! D5 fixture (clean): the faults directory recovers poisoned locks
//! and returns values instead of panicking.

pub fn plan_rate(plan: &Plan) -> Option<u32> {
    let slot = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    slot.as_ref()?;
    plan.rates.first().copied()
}
