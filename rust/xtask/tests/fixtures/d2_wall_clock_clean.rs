//! D2 fixture (clean): progress measured by work counters, not clocks.

pub fn counted_work(budget: u64) -> u64 {
    let mut done = 0u64;
    while done < budget {
        done += 1;
    }
    done
}
