//! D5 fixture: panics reachable from the wire path.

pub fn first_field(p: &[Value]) -> f64 {
    let head = p[0].as_f64();
    head.unwrap()
}
