//! D3 fixture: raw distance math outside the counted kernels.
use crate::metrics::dense_dot;

pub fn sim(a: &[f32], b: &[f32]) -> f64 {
    dense_dot(a, b)
}
