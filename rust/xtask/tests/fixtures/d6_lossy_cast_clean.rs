//! D6 fixture (clean): checked conversion through the ids helpers.

pub fn decode_id(raw: f64) -> Result<u64, String> {
    crate::ids::wire_u64(raw, "id")
}
