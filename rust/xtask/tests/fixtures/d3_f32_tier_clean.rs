//! D3 fixture (clean): f32 filter-tier work routed through the counted
//! block helper — it bumps both counter cells itself, so no token fires.
use crate::metrics::{block, Space};

pub fn prune(space: &Space, q: &[f32], out_r: &mut Vec<u32>, out_d: &mut Vec<f64>) {
    if let Some(f) = block::F32Filter::new(space, q) {
        let q_sq = q.iter().map(|&x| x as f64 * x as f64).sum();
        block::dists_contig_to_vec_f32(space, 0..space.n(), q, q_sq, &f, 1.0, out_r, out_d);
    }
}
