//! D1 fixture (clean): BTreeMap iterates in key order on every run.
use std::collections::BTreeMap;

pub fn merge(xs: &[(u32, f64)]) -> Vec<(u32, f64)> {
    let mut m = BTreeMap::new();
    for &(k, v) in xs {
        m.insert(k, v);
    }
    m.into_iter().collect()
}
