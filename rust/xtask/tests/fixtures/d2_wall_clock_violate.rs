//! D2 fixture: wall-clock reads in algorithm code.
use std::time::Instant;

pub fn timed_work() -> f64 {
    let start = Instant::now();
    expensive();
    start.elapsed().as_secs_f64()
}

fn expensive() {}
