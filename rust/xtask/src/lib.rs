//! Repo tooling for the anchors-hierarchy workspace.
//!
//! The only subcommand today is `lint` — a std-only static-analysis pass
//! (`pallas-lint`) that enforces the determinism & accounting contract at
//! the source level. See [`lint`] and `docs/LINTS.md`.

pub mod lint;
