//! `cargo run -p xtask -- lint [--root <repo-root>]`
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = match root_arg(&args) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("xtask: {e}");
                    return ExitCode::from(2);
                }
            };
            if xtask::lint::run(&root) == 0 {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--root <repo-root>]");
            ExitCode::from(2)
        }
    }
}

/// `--root DIR` if given, else the first ancestor of the current directory
/// containing `rust/src`.
fn root_arg(args: &[String]) -> Result<PathBuf, String> {
    if let Some(pos) = args.iter().position(|a| a == "--root") {
        let dir = args
            .get(pos + 1)
            .ok_or_else(|| "--root needs a directory argument".to_string())?;
        return Ok(PathBuf::from(dir));
    }
    let mut dir = std::env::current_dir().map_err(|e| e.to_string())?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            return Err("no repo root found (no `rust/src` in any ancestor)".to_string());
        }
    }
}
