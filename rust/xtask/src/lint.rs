//! `pallas-lint`: source-level enforcement of the determinism & accounting
//! contract (see `docs/LINTS.md`).
//!
//! The whole reproduction rests on two invariants the type system cannot
//! see: results must be a pure function of the inputs (bit-identical at
//! every thread/shard count), and every point-to-point distance must be
//! counted exactly once (the paper's eq.-6 accounting). This pass
//! tokenizes every `.rs` file — comments and string/char literals
//! stripped, `#[cfg(test)]` modules skipped — and denies the source
//! patterns that historically break those invariants:
//!
//! | rule            | denies                                              |
//! |-----------------|-----------------------------------------------------|
//! | `hash-order`    | hash-ordered containers in result-producing paths   |
//! | `wall-clock`    | time/env reads in algorithm/tree/metrics/engine code|
//! | `uncounted-dist`| raw coordinate math outside the counted kernels     |
//! | `threads`       | thread primitives outside `parallel/`/`coordinator/`|
//! | `panic-wire`    | unwrap/expect/panic/index panics in wire handling   |
//! | `lossy-cast`    | lossy `as` casts on id/count/wire values            |
//!
//! Suppression is scoped and audited: `// pallas-lint: allow(rule, reason)`
//! on the offending line (trailing) or on comment lines directly above it.
//! The reason is mandatory — an allow without one is itself an error
//! (rule `bad-allow`).

use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation (or malformed directive) at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the repo root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name (one of [`RULE_NAMES`], or `bad-allow`).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Result of linting one file.
#[derive(Debug, Default)]
pub struct Report {
    /// Violations that were **not** suppressed, in line order.
    pub diagnostics: Vec<Diagnostic>,
    /// Violations suppressed by a `pallas-lint: allow(rule, reason)`.
    pub suppressed: usize,
}

/// Names of the deny-by-default rules, in D1–D6 order.
pub const RULE_NAMES: [&str; 6] = [
    "hash-order",
    "wall-clock",
    "uncounted-dist",
    "threads",
    "panic-wire",
    "lossy-cast",
];

// ---------------------------------------------------------------------------
// Rule scopes (path prefixes / exact files, relative to the repo root).
// ---------------------------------------------------------------------------

/// D1: result-producing paths where iteration order reaches outputs.
const HASH_FREE_DIRS: [&str; 5] = [
    "rust/src/algorithms/",
    "rust/src/tree/",
    "rust/src/engine/",
    "rust/src/metrics/",
    "rust/src/anchors/",
];

/// D2: pure-algorithm code — no clocks, no environment. `engine/` is in
/// scope too: `Index::run_traced` returns *deterministic* traversal
/// counters, never timings. The sanctioned homes for clocks are the
/// observability module (`obs/` measures nothing itself, but hosts the
/// histogram/trace plumbing) and the serving edge (`coordinator/`,
/// `main.rs`, `bench/`), which are simply outside this scope.
const CLOCK_FREE_DIRS: [&str; 5] = [
    "rust/src/algorithms/",
    "rust/src/tree/",
    "rust/src/metrics/",
    "rust/src/anchors/",
    "rust/src/engine/",
];

/// D3: code that must route distance math through the counted kernels.
/// `metrics/` and `data.rs` are exempt: they *implement* those kernels.
const COUNTED_DIRS: [&str; 4] = [
    "rust/src/algorithms/",
    "rust/src/tree/",
    "rust/src/engine/",
    "rust/src/anchors/",
];

/// D4: the only homes for thread primitives.
const THREAD_EXEMPT_DIRS: [&str; 2] = ["rust/src/parallel/", "rust/src/coordinator/"];

/// D5: wire-facing code where a panic kills a client connection.
/// `shard.rs` is in scope because the sharded router sits directly on
/// the request path (routing, drain, cancel) — a panic there takes the
/// whole serving edge down, not one job.
const WIRE_FILES: [&str; 4] = [
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/shard.rs",
    "rust/src/engine/wire.rs",
    "rust/src/json.rs",
];

/// D5, directory form: fault-injection code runs on failure paths by
/// definition — the harness that forces failures must never add its
/// own panic on top of the one it is injecting.
const WIRE_DIRS: [&str; 1] = ["rust/src/faults/"];

/// D6: id/count/wire conversion surfaces (checked helpers live in
/// `crate::ids`, which is the one sanctioned home for the raw casts).
const CAST_FILES: [&str; 4] = [
    "rust/src/engine/wire.rs",
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/shard.rs",
    "rust/src/tree/serialize.rs",
];

// ---------------------------------------------------------------------------
// Rule token tables.
// ---------------------------------------------------------------------------

const HASH_TOKENS: [&str; 6] = [
    "HashMap",
    "HashSet",
    "RandomState",
    "DefaultHasher",
    "hash_map",
    "hash_set",
];

const CLOCK_TOKENS: [&str; 6] = [
    "std::time",
    "Instant",
    "SystemTime",
    "std::env",
    "env::var",
    "elapsed",
];

const UNCOUNTED_TOKENS: [&str; 13] = [
    "dist_uncounted",
    "dist_to_vec_uncounted",
    "dense_dot",
    "dense_sqdist",
    "dense_euclidean",
    "dense_l1",
    "dot_rows",
    "dot_vec",
    "rows_slab",
    ".row(",
    // f32 filter-tier entry points. Token matching is identifier-exact,
    // so `dense_dot` above does NOT cover `dense_dot_f32` — each raw f32
    // kernel needs its own token. `block::dists_contig_to_vec_f32` is
    // fine to call (it bumps both counter cells itself), but algorithm
    // code reaching for the raw kernels or the f32 slab bypasses the
    // f32_evals accounting exactly like the f64 tokens above.
    "dense_dot_f32",
    "dot_vec_f32",
    "rows_slab_f32",
];

const THREAD_TOKENS: [&str; 5] = [
    "std::thread",
    "thread::spawn",
    "thread::scope",
    "thread::Builder",
    "JoinHandle",
];

const PANIC_TOKENS: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

const CAST_TOKENS: [&str; 10] = [
    " as usize",
    " as u64",
    " as u32",
    " as u16",
    " as u8",
    " as i64",
    " as i32",
    " as i16",
    " as i8",
    " as f64",
];

fn rule_hint(rule: &str) -> &'static str {
    match rule {
        "hash-order" => {
            "hash-ordered container in a result-producing path; per-instance \
             RandomState makes iteration order nondeterministic — use \
             BTreeMap/BTreeSet or sort before iterating"
        }
        "wall-clock" => {
            "wall-clock or environment read inside algorithm code; results \
             must be a pure function of the inputs — timing and config \
             belong at the serving edge (obs/, coordinator/, bench/, main.rs)"
        }
        "uncounted-dist" => {
            "raw coordinate math outside the counted kernels; route through \
             Space::dist/dist2/dist_to_vec or metrics::block, or pair the \
             call with Space::count_bulk so eq.-6 accounting stays exact"
        }
        "threads" => {
            "thread primitive outside parallel/ and coordinator/; all \
             fan-out goes through parallel::Executor's fixed decomposition"
        }
        "panic-wire" => {
            "potential panic in wire/server code; malformed client input \
             must produce an ok:false error response, never kill the \
             connection thread"
        }
        "lossy-cast" => {
            "lossy `as` cast on an id/count/wire value; use the checked \
             helpers in crate::ids (or From/try_from for infallible widths)"
        }
        _ => "",
    }
}

// ---------------------------------------------------------------------------
// Sanitizer: split source into lines of (code, comment) with string and
// char literal contents removed, so token matching never fires inside
// literals and directives can be read from comment text.
// ---------------------------------------------------------------------------

/// One source line: `code` with literals blanked, `comment` text joined.
#[derive(Debug, Default, Clone)]
struct SrcLine {
    code: String,
    comment: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

/// `r"`, `r#"`, `br"` … starting at `i`: returns (hash count, index past
/// the opening quote).
fn raw_string_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

fn sanitize(src: &str) -> Vec<SrcLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = SrcLine::default();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((hashes, after)) = raw_string_open(&chars, i) {
                        cur.code.push('"');
                        mode = Mode::RawStr(hashes);
                        i = after;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs. lifetime: a literal is 'x' or an
                    // escape; anything else ('a in generics, 'static) is a
                    // lifetime and stays in the code text.
                    let is_char_lit = match chars.get(i + 1) {
                        Some('\\') => true,
                        Some(&n) if n != '\'' => chars.get(i + 2) == Some(&'\''),
                        _ => false,
                    };
                    if is_char_lit {
                        mode = Mode::CharLit;
                    } else {
                        cur.code.push('\'');
                    }
                    i += 1;
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    mode = if depth <= 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' && chars.get(i + 1) == Some(&'\n') {
                    i += 1; // let the newline be processed normally
                } else if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut k = i + 1;
                    let mut seen = 0u32;
                    while seen < hashes && chars.get(k) == Some(&'#') {
                        seen += 1;
                        k += 1;
                    }
                    if seen == hashes {
                        cur.code.push('"');
                        mode = Mode::Code;
                        i = k;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            Mode::CharLit => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '\'' {
                        mode = Mode::Code;
                    }
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

// ---------------------------------------------------------------------------
// `#[cfg(test)] mod … { }` skipping: test code may time, spawn and unwrap.
// ---------------------------------------------------------------------------

fn brace_delta(code: &str) -> i32 {
    let mut d = 0;
    for c in code.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
    }
    d
}

/// Per-line flag: true when the line belongs to a `#[cfg(test)]` item.
fn test_mod_lines(lines: &[SrcLine]) -> Vec<bool> {
    let mut flags = vec![false; lines.len()];
    let mut pending_attr = false;
    let mut inside = false;
    let mut depth: i32 = 0;
    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.trim();
        if inside {
            flags[idx] = true;
            depth += brace_delta(code);
            if depth <= 0 {
                inside = false;
            }
            continue;
        }
        if code.contains("#[cfg(test)]") {
            pending_attr = true;
            flags[idx] = true;
            continue;
        }
        if pending_attr {
            flags[idx] = true;
            if code.is_empty() || code.starts_with("#[") {
                continue; // further attributes between cfg(test) and item
            }
            pending_attr = false;
            if code.starts_with("mod ") || code.starts_with("pub mod ") {
                depth = brace_delta(code);
                if depth > 0 {
                    inside = true;
                }
            }
            // cfg(test) on a single-line non-mod item: that line is already
            // flagged; multi-line test items outside a test mod are not a
            // pattern this repo uses.
        }
    }
    flags
}

// ---------------------------------------------------------------------------
// Suppression directives.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
enum Directive {
    None,
    Allow(&'static str),
    Malformed(String),
}

fn parse_directive(comment: &str) -> Directive {
    let Some(pos) = comment.find("pallas-lint:") else {
        return Directive::None;
    };
    let rest = comment[pos + "pallas-lint:".len()..].trim_start();
    let Some(body) = rest.strip_prefix("allow(") else {
        return Directive::Malformed(
            "expected `pallas-lint: allow(<rule>, <reason>)`".to_string(),
        );
    };
    let Some(close) = body.rfind(')') else {
        return Directive::Malformed("unclosed `pallas-lint: allow(` directive".to_string());
    };
    let Some((rule, reason)) = body[..close].split_once(',') else {
        return Directive::Malformed(
            "allow directive needs a non-empty reason: allow(<rule>, <reason>)".to_string(),
        );
    };
    let rule = rule.trim();
    let Some(rule) = RULE_NAMES.iter().copied().find(|r| *r == rule) else {
        return Directive::Malformed(format!("unknown rule `{rule}` in allow directive"));
    };
    if reason.trim().is_empty() {
        return Directive::Malformed(
            "allow directive needs a non-empty reason: allow(<rule>, <reason>)".to_string(),
        );
    }
    Directive::Allow(rule)
}

// ---------------------------------------------------------------------------
// Token matching.
// ---------------------------------------------------------------------------

/// Substring search honoring identifier boundaries on whichever ends of
/// the token are identifier characters ("Instant" does not match
/// "InstantLike"; ".row(" matches only an actual method call).
fn has_token(code: &str, tok: &str) -> bool {
    let first_ident = tok.chars().next().is_some_and(is_ident_char);
    let last_ident = tok.chars().last().is_some_and(is_ident_char);
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        let before_ok =
            !first_ident || !code[..at].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !last_ident
            || !code[at + tok.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return true;
        }
        start = at + tok.len();
    }
    false
}

/// `expr[0]`-style indexing with a bare integer literal (an out-of-range
/// panic waiting on malformed input). Array literals (`[0u8; 4]`, `&[0]`)
/// and ranges (`[lo..hi]`) do not match: the bracket must directly follow
/// an expression and enclose only digits.
fn has_literal_index(code: &str) -> bool {
    let bytes = code.as_bytes();
    for i in 1..bytes.len() {
        if bytes[i] == b'['
            && (bytes[i - 1].is_ascii_alphanumeric() || matches!(bytes[i - 1], b'_' | b')' | b']'))
        {
            let mut j = i + 1;
            while j < bytes.len() && bytes[j].is_ascii_digit() {
                j += 1;
            }
            if j > i + 1 && j < bytes.len() && bytes[j] == b']' {
                return true;
            }
        }
    }
    false
}

fn in_dirs(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| path.starts_with(d))
}

fn is_use_line(code: &str) -> bool {
    // Imports are not uses of the behavior; the call sites are flagged.
    code.starts_with("use ")
        || code.starts_with("pub use ")
        || code.starts_with("pub(crate) use ")
}

/// All rule violations on one sanitized, non-test, non-import code line.
fn check_rules(path: &str, code: &str, found: &mut Vec<(&'static str, String)>) {
    let mut push = |rule: &'static str, what: &str| {
        found.push((rule, format!("`{what}` — {}", rule_hint(rule))));
    };
    if in_dirs(path, &HASH_FREE_DIRS) {
        for tok in HASH_TOKENS {
            if has_token(code, tok) {
                push("hash-order", tok);
            }
        }
    }
    if in_dirs(path, &CLOCK_FREE_DIRS) {
        for tok in CLOCK_TOKENS {
            if has_token(code, tok) {
                push("wall-clock", tok);
            }
        }
    }
    if in_dirs(path, &COUNTED_DIRS) {
        for tok in UNCOUNTED_TOKENS {
            if has_token(code, tok) {
                push("uncounted-dist", tok);
            }
        }
    }
    if path.starts_with("rust/src/") && !in_dirs(path, &THREAD_EXEMPT_DIRS) {
        for tok in THREAD_TOKENS {
            if has_token(code, tok) {
                push("threads", tok);
            }
        }
    }
    if WIRE_FILES.contains(&path) || in_dirs(path, &WIRE_DIRS) {
        for tok in PANIC_TOKENS {
            if has_token(code, tok) {
                push("panic-wire", tok);
            }
        }
        if has_literal_index(code) {
            push("panic-wire", "[<int>] indexing");
        }
    }
    if CAST_FILES.contains(&path) {
        for tok in CAST_TOKENS {
            if has_token(code, tok) {
                push("lossy-cast", tok.trim_start());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Per-file driver.
// ---------------------------------------------------------------------------

/// Lint one file's source. `path` must be repo-root-relative with `/`
/// separators — rule scopes are path-based.
pub fn lint_source(path: &str, src: &str) -> Report {
    let path = path.replace('\\', "/");
    let lines = sanitize(src);
    let in_test = test_mod_lines(&lines);
    let mut diagnostics = Vec::new();
    let mut suppressed = 0usize;
    // Allow directives on a run of comment-only lines directly above the
    // line they suppress.
    let mut pending_allows: Vec<&'static str> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let directive = parse_directive(&line.comment);
        if let Directive::Malformed(why) = &directive {
            diagnostics.push(Diagnostic {
                file: path.clone(),
                line: lineno,
                rule: "bad-allow",
                msg: why.clone(),
            });
        }
        let code = line.code.trim();
        let mut found = Vec::new();
        if !in_test[idx] && !code.is_empty() && !is_use_line(code) {
            check_rules(&path, code, &mut found);
        }
        let mut active = pending_allows.clone();
        if let Directive::Allow(rule) = &directive {
            active.push(rule);
        }
        for (rule, msg) in found {
            if active.contains(&rule) {
                suppressed += 1;
            } else {
                diagnostics.push(Diagnostic {
                    file: path.clone(),
                    line: lineno,
                    rule,
                    msg,
                });
            }
        }
        let comment_only = code.is_empty() && !line.comment.trim().is_empty();
        if comment_only {
            if let Directive::Allow(rule) = &directive {
                pending_allows.push(rule);
            }
        } else {
            pending_allows.clear();
        }
    }
    Report {
        diagnostics,
        suppressed,
    }
}

// ---------------------------------------------------------------------------
// Repo driver.
// ---------------------------------------------------------------------------

/// Directories scanned, relative to the repo root (missing ones skipped).
const SCAN_DIRS: [&str; 5] = [
    "rust/src",
    "rust/tests",
    "rust/benches",
    "rust/examples",
    "examples",
];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Run the linter over the repo rooted at `root`, printing `file:line`
/// diagnostics and a summary. Returns the number of violations.
pub fn run(root: &Path) -> usize {
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs(&root.join(dir), &mut files);
    }
    files.sort();
    let mut all = Vec::new();
    let mut suppressed = 0usize;
    let mut scanned = 0usize;
    for file in &files {
        let Ok(src) = std::fs::read_to_string(file) else {
            eprintln!("pallas-lint: cannot read {}", file.display());
            continue;
        };
        scanned += 1;
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        let report = lint_source(&rel, &src);
        suppressed += report.suppressed;
        all.extend(report.diagnostics);
    }
    for d in &all {
        println!("{d}");
    }
    println!(
        "pallas-lint: {scanned} file(s) scanned, {} violation(s), {suppressed} suppressed by allow",
        all.len()
    );
    all.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_strips_comments_and_strings() {
        let src = "let x = \"HashMap\"; // HashMap here\nlet y = 1;\n";
        let lines = sanitize(src);
        assert_eq!(lines.len(), 2);
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].comment.contains("HashMap"));
        assert_eq!(lines[1].code.trim(), "let y = 1;");
    }

    #[test]
    fn sanitize_handles_raw_strings_and_chars() {
        let src = "let s = r#\"dense_dot ) \"#;\nlet c = '\\'';\nlet lt: &'static str = e;\n";
        let lines = sanitize(src);
        assert!(!lines[0].code.contains("dense_dot"));
        assert!(lines[1].code.contains("let c ="));
        assert!(lines[2].code.contains("'static"));
    }

    #[test]
    fn sanitize_handles_block_comments() {
        let src = "let a = 1; /* dense_dot\nstill comment */ let b = 2;\n";
        let lines = sanitize(src);
        assert!(!lines[0].code.contains("dense_dot"));
        assert!(lines[1].code.contains("let b = 2;"));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("let m: HashMap<u32, u32> = x;", "HashMap"));
        assert!(!has_token("let m = MyHashMapLike::new();", "HashMap"));
        assert!(has_token("let d = dense_dot(a, b);", "dense_dot"));
        assert!(has_token("let r = m.row(3);", ".row("));
        assert!(!has_token("space.fill_row(3, buf);", ".row("));
        assert!(!has_token("x.borrow()", ".row("));
        assert!(has_token("let k = v as usize;", " as usize"));
        assert!(!has_token("let k = v as usize_wrapper;", " as usize"));
    }

    #[test]
    fn literal_index_detection() {
        assert!(has_literal_index("let a = p[0];"));
        assert!(has_literal_index("q[17].clone()"));
        assert!(!has_literal_index("let a = &[0u8];"));
        assert!(!has_literal_index("let a = [0];"));
        assert!(!has_literal_index("let a = p[i];"));
        assert!(!has_literal_index("let a = p[0..2];"));
    }

    #[test]
    fn directive_parsing() {
        assert_eq!(parse_directive("no directive here"), Directive::None);
        assert_eq!(
            parse_directive(" pallas-lint: allow(hash-order, keys sorted first)"),
            Directive::Allow("hash-order")
        );
        assert!(matches!(
            parse_directive(" pallas-lint: allow(hash-order)"),
            Directive::Malformed(_)
        ));
        assert!(matches!(
            parse_directive(" pallas-lint: allow(hash-order, )"),
            Directive::Malformed(_)
        ));
        assert!(matches!(
            parse_directive(" pallas-lint: allow(no-such-rule, reason)"),
            Directive::Malformed(_)
        ));
        assert!(matches!(
            parse_directive(" pallas-lint: deny(hash-order)"),
            Directive::Malformed(_)
        ));
    }

    #[test]
    fn cfg_test_mod_is_skipped() {
        let src = "fn a() {}\n\n#[cfg(test)]\nmod tests {\n    use std::time::Instant;\n    \
                   fn t() { let _ = Instant::now(); }\n}\n";
        let report = lint_source("rust/src/algorithms/x.rs", src);
        assert!(report.diagnostics.is_empty(), "{:?}", report.diagnostics);
    }

    #[test]
    fn allow_suppresses_same_line_and_above() {
        let src = "// pallas-lint: allow(uncounted-dist, counted via count_bulk below)\n\
                   let d = dense_dot(a, b);\n\
                   let e = dense_dot(a, b); // pallas-lint: allow(uncounted-dist, staging)\n";
        let report = lint_source("rust/src/algorithms/x.rs", src);
        assert!(report.diagnostics.is_empty(), "{:?}", report.diagnostics);
        assert_eq!(report.suppressed, 2);
    }

    #[test]
    fn allow_does_not_leak_past_code_lines() {
        let src = "// pallas-lint: allow(uncounted-dist, first line only)\n\
                   let d = dense_dot(a, b);\n\
                   let e = dense_dot(a, b);\n";
        let report = lint_source("rust/src/algorithms/x.rs", src);
        assert_eq!(report.diagnostics.len(), 1);
        assert_eq!(report.diagnostics[0].line, 3);
    }

    #[test]
    fn use_lines_are_not_flagged() {
        let src = "use crate::metrics::{dense_dot, Space};\n";
        let report = lint_source("rust/src/algorithms/x.rs", src);
        assert!(report.diagnostics.is_empty(), "{:?}", report.diagnostics);
    }

    #[test]
    fn scopes_gate_rules() {
        // dense_dot inside metrics/ (kernel home) is fine…
        let src = "let d = dense_dot(a, b);\n";
        assert!(lint_source("rust/src/metrics/block.rs", src)
            .diagnostics
            .is_empty());
        // …but not in algorithms/.
        assert_eq!(
            lint_source("rust/src/algorithms/x.rs", src).diagnostics.len(),
            1
        );
        // Threads are fine in parallel/, not in algorithms/.
        let spawn = "let h = std::thread::spawn(f);\n";
        assert!(lint_source("rust/src/parallel/pool.rs", spawn)
            .diagnostics
            .is_empty());
        let flagged = lint_source("rust/src/algorithms/x.rs", spawn);
        assert!(!flagged.diagnostics.is_empty());
        assert!(flagged.diagnostics.iter().all(|d| d.rule == "threads"));
    }
}
