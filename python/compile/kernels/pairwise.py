"""Layer-1 Pallas kernel: tiled squared-Euclidean pairwise distances.

This is the numeric hot-spot of the whole stack: the unprunable residue of
the metric-tree algorithms (leaf-level point-vs-candidate blocks in
K-means, dense naive baselines) is exactly an (N x D) . (D x K)
contraction plus row/column norms.

TPU mapping (see DESIGN.md #Hardware-Adaptation): the grid tiles the
output into (bn, bk) blocks; each grid step holds an x-tile [bn, d], a
c-tile [bk, d] and the out-tile [bn, bk] in VMEM, and the inner
``x @ c.T`` maps onto the MXU systolic array. The d (feature) axis stays
resident - for the AOT variants we ship (d <= 1024, bn = 256, bk = 128)
the VMEM footprint is (bn*d + bk*d + bn*bk) * 4B ~= 1.7 MB at d = 1024,
comfortably under the ~16 MB VMEM budget, leaving room for
double-buffering the HBM->VMEM pipeline.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter
into plain HLO. Correctness vs kernels/ref.py is enforced by pytest and
a hypothesis shape sweep.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-tile shape. bn is the point-axis tile, bk the center-axis
# tile. 256 x 128 keeps the MXU-shaped contraction wide while bounding
# VMEM (see module docstring).
DEFAULT_BN = 256
DEFAULT_BK = 128


def _pairwise_d2_kernel(x_ref, c_ref, o_ref):
    """One grid step: o[bn, bk] = ||x||^2 - 2 x c^T + ||c||^2.

    The expansion form is used (instead of materializing the [bn, bk, d]
    difference tensor) so the core is a single MXU-friendly matmul and the
    VMEM high-water mark stays at the three resident tiles.
    """
    x = x_ref[...]
    c = c_ref[...]
    # Row norms: [bn, 1] and [1, bk]; computed on the VPU.
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T
    # The MXU contraction. preferred_element_type pins the accumulator to
    # f32 even if inputs were cast to bf16 on a real TPU.
    xc = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Squared distances are mathematically >= 0; the expansion can go
    # slightly negative in float - clamp so sqrt() downstream is safe.
    o_ref[...] = jnp.maximum(xn + cn - 2.0 * xc, 0.0)


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def pairwise_d2(x, c, *, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Tiled pairwise squared-Euclidean distances via Pallas.

    Args:
      x: [n, d]; n must be a multiple of bn (callers pad; zero-padding is
         exact for squared Euclidean distances).
      c: [k, d]; k must be a multiple of bk.
      bn, bk: output tile shape.

    Returns:
      [n, k] float32 squared distances.
    """
    n, d = x.shape
    k, d2 = c.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert n % bn == 0, f"n={n} not a multiple of bn={bn}"
    assert k % bk == 0, f"k={k} not a multiple of bk={bk}"
    grid = (n // bn, k // bk)
    return pl.pallas_call(
        _pairwise_d2_kernel,
        grid=grid,
        in_specs=[
            # x-tile varies along grid axis 0 only; the full feature axis
            # is resident (block d = d).
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            # c-tile varies along grid axis 1 only.
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x.astype(jnp.float32), c.astype(jnp.float32))
