"""Pure-jnp oracles for the Pallas kernels.

These are the CORRECTNESS ground truth: every Pallas kernel in this
package must match its oracle to float32 tolerance under pytest (see
python/tests/). They are deliberately written in the most direct form
(no expansion tricks) so a bug in the optimized kernel cannot be
mirrored here.
"""

import jax.numpy as jnp


def pairwise_d2_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance between every row of x and every row of c.

    Args:
      x: [n, d] float array of points.
      c: [k, d] float array of centers.

    Returns:
      [n, k] with out[i, j] = sum_t (x[i, t] - c[j, t])**2.
    """
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def assign_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Index of the closest center for every point (ties -> lowest index)."""
    return jnp.argmin(pairwise_d2_ref(x, c), axis=1).astype(jnp.int32)


def kmeans_accumulate_ref(x, c, xmask, cmask):
    """One dense K-means accumulation pass (oracle for model.kmeans_accumulate).

    Args:
      x: [n, d] points; rows with xmask == 0 are padding and must not
         contribute to any output.
      c: [k, d] centers; columns with cmask == 0 are padding and must never
         win an assignment.
      xmask: [n] float 0/1.
      cmask: [k] float 0/1.

    Returns:
      counts:  [k]   number of real points assigned to each center.
      sums:    [k,d] per-center sum of assigned real points.
      distortion: [] sum over real points of squared distance to the
                  closest real center.
      assign:  [n] int32 index of the closest real center (padding rows get
               whatever argmin produces; callers must mask by xmask).
    """
    big = jnp.float32(1e30)
    d2 = pairwise_d2_ref(x, c) + (1.0 - cmask)[None, :] * big
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2 = jnp.min(d2, axis=1)
    onehot = jnp.eye(c.shape[0], dtype=x.dtype)[assign] * xmask[:, None]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x
    distortion = jnp.sum(mind2 * xmask)
    return counts, sums, distortion, assign
