"""AOT compile path: lower every (program, shape) variant to HLO TEXT.

HLO *text* (not ``lowered.compile().serialize()``, not a serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The HLO text parser on the rust side
(``HloModuleProto::from_text_file``) reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts``; emits artifacts/<program>_n{n}_k{k}_d{d}.hlo.txt
plus artifacts/manifest.json describing every variant (consumed by
rust/src/runtime/artifacts.rs).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(program: str, n: int, k: int, d: int) -> str:
    spec = model.PROGRAMS[program]
    args = spec["args"](n, k, d)
    lowered = jax.jit(spec["fn"]).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--feature-widths",
        type=int,
        nargs="*",
        default=list(model.FEATURE_WIDTHS),
        help="padded feature-width variants to emit",
    )
    ap.add_argument("--tile-n", type=int, default=model.TILE_N)
    ap.add_argument("--tile-k", type=int, default=model.TILE_K)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"tile_n": args.tile_n, "tile_k": args.tile_k, "variants": []}
    for program, spec in model.PROGRAMS.items():
        for d in args.feature_widths:
            n, k = args.tile_n, args.tile_k
            fname = f"{program}_n{n}_k{k}_d{d}.hlo.txt"
            text = lower_variant(program, n, k, d)
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["variants"].append(
                {
                    "program": program,
                    "n": n,
                    "k": k,
                    "d": d,
                    "file": fname,
                    "outputs": spec["outputs"],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}: {len(manifest['variants'])} variants")


if __name__ == "__main__":
    main()
