"""Layer-2 JAX compute graphs, AOT-lowered to HLO text for the rust runtime.

Every public function here is a pure jax function over fixed shapes; the
Pallas kernel (kernels/pairwise.py) supplies the inner contraction so it
lowers into the same HLO module. aot.py lowers each (program, shape)
variant once; python never runs on the rust request path.

Programs:
  pairwise_d2(x, c)                      -> (d2,)
  kmeans_accumulate(x, c, xmask, cmask)  -> (counts, sums, distortion, assign)
  range_count(x, q, xmask, radius2)      -> (counts,)

Padding contract (mirrored by rust/src/runtime/):
  * points / centers are zero-padded up to the variant's (n, k); zero
    padding is EXACT for squared Euclidean distances along d.
  * xmask/cmask mark real rows; padded centers get +1e30 added to their
    distance column so they can never win an argmin.
"""

import jax
import jax.numpy as jnp

from .kernels.pairwise import pairwise_d2 as _pallas_pairwise_d2

BIG = 1e30  # Additive penalty that disqualifies padded centers.


def _block(dim: int, default: int) -> int:
    """Largest usable tile: the default when it divides dim, else the whole
    axis (small-shape testing path; AOT variants always use the default)."""
    return default if dim % default == 0 else dim


def pairwise_d2(x, c):
    """Squared-distance matrix [n, k] (Pallas-tiled). Returns a 1-tuple."""
    from .kernels import pairwise as pw

    bn = _block(x.shape[0], pw.DEFAULT_BN)
    bk = _block(c.shape[0], pw.DEFAULT_BK)
    return (_pallas_pairwise_d2(x, c, bn=bn, bk=bk),)


def kmeans_accumulate(x, c, xmask, cmask):
    """One dense K-means accumulation pass over a tile of points.

    The naive (treeless) K-means baseline in rust streams point tiles
    through this program and sums the outputs; the tree-accelerated path
    uses it at leaf nodes where several candidate centroids survive
    pruning.

    Args:
      x: [n, d] points (zero-padded rows allowed).
      c: [k, d] centers (zero-padded rows allowed).
      xmask: [n] 1.0 for real points, 0.0 for padding.
      cmask: [k] 1.0 for real centers, 0.0 for padding.

    Returns:
      counts [k], sums [k, d], distortion [] (sum of min-d2 over real
      points), assign [n] int32.
    """
    (d2,) = pairwise_d2(x, c)
    d2 = d2 + (1.0 - cmask)[None, :] * jnp.float32(BIG)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2 = jnp.min(d2, axis=1)
    # One-hot scatter of point masses to their winning centers. The
    # one-hot matmul keeps everything dense + fusable (no gather/scatter),
    # which XLA fuses with the mask multiply.
    onehot = (
        (assign[:, None] == jnp.arange(c.shape[0], dtype=jnp.int32)[None, :])
        .astype(x.dtype)
        * xmask[:, None]
    )
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x
    distortion = jnp.sum(mind2 * xmask)
    return counts, sums, distortion, assign


def range_count(x, q, xmask, radius2):
    """Count, for each query row q[j], the real points within sqrt(radius2).

    Used by the anomaly-detection naive baseline: counts[j] = |{i : xmask[i]
    and D2(x_i, q_j) <= radius2[j]}|.

    Args:
      x: [n, d] dataset tile, q: [k, d] query tile, xmask: [n],
      radius2: [k] per-query squared radius.

    Returns:
      (counts [k] float32,)
    """
    (d2,) = pairwise_d2(x, q)
    inside = (d2 <= radius2[None, :]).astype(jnp.float32) * xmask[:, None]
    return (jnp.sum(inside, axis=0),)


# ---------------------------------------------------------------------------
# AOT variant registry. Feature widths cover Table 1 of the paper: 2-d
# synthetic (->8), cell 38 (->64), covtype 54 (->64), gen100 (->128),
# gen1000 (->1024), reuters 4732 (feature-hashed ->1024 by the rust side).
# n/k tile sizes match the Pallas block shape so no intra-call remainder
# handling is needed.
# ---------------------------------------------------------------------------

FEATURE_WIDTHS = (8, 64, 128, 256, 1024)
TILE_N = 256
TILE_K = 128

PROGRAMS = {
    "pairwise_d2": {
        "fn": pairwise_d2,
        "args": lambda n, k, d: (
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
        ),
        "outputs": ["d2[n,k]f32"],
    },
    "kmeans_accumulate": {
        "fn": kmeans_accumulate,
        "args": lambda n, k, d: (
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ),
        "outputs": ["counts[k]f32", "sums[k,d]f32", "distortion[]f32", "assign[n]i32"],
    },
    "range_count": {
        "fn": range_count,
        "args": lambda n, k, d: (
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ),
        "outputs": ["counts[k]f32"],
    },
}
