"""L2 correctness: model programs vs oracles, padding contract, AOT lowering."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import kmeans_accumulate_ref, pairwise_d2_ref


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


def _masks(n, k, n_real, k_real):
    xm = jnp.asarray([1.0] * n_real + [0.0] * (n - n_real), dtype=jnp.float32)
    cm = jnp.asarray([1.0] * k_real + [0.0] * (k - k_real), dtype=jnp.float32)
    return xm, cm


class TestKmeansAccumulate:
    def test_matches_ref_full(self):
        x, c = _rand((32, 8), 1), _rand((8, 8), 2)
        xm, cm = _masks(32, 8, 32, 8)
        got = model.kmeans_accumulate(x, c, xm, cm)
        want = kmeans_accumulate_ref(x, c, xm, cm)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)

    def test_padded_rows_do_not_contribute(self):
        x, c = _rand((16, 4), 3), _rand((8, 4), 4)
        # zero out the padding rows the way rust does
        x = x.at[10:].set(0.0)
        c = c.at[5:].set(0.0)
        xm, cm = _masks(16, 8, 10, 5)
        counts, sums, distortion, assign = model.kmeans_accumulate(x, c, xm, cm)
        # Compare against an unpadded oracle run.
        wc, ws, wd, wa = kmeans_accumulate_ref(
            x[:10], c[:5], jnp.ones(10), jnp.ones(5)
        )
        np.testing.assert_allclose(counts[:5], wc, atol=1e-5)
        np.testing.assert_allclose(counts[5:], 0.0, atol=1e-5)
        np.testing.assert_allclose(sums[:5], ws, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(distortion, wd, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(assign[:10]), np.asarray(wa))

    def test_mass_conservation(self):
        x, c = _rand((64, 8), 5), _rand((16, 8), 6)
        xm, cm = _masks(64, 16, 50, 12)
        x = x * xm[:, None]
        counts, sums, _, _ = model.kmeans_accumulate(x, c, xm, cm)
        assert float(jnp.sum(counts)) == pytest.approx(50.0)
        np.testing.assert_allclose(
            jnp.sum(sums, axis=0), jnp.sum(x, axis=0), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=25, deadline=None)
    @given(
        n_real=st.integers(1, 24),
        k_real=st.integers(1, 8),
        d=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_padding_invariance(self, n_real, k_real, d, seed):
        # Whatever the real sizes, padding to the tile must not change the
        # restriction of the outputs to the real prefix.
        n, k = 24, 8
        x = _rand((n, d), seed)
        c = _rand((k, d), seed + 1)
        xm, cm = _masks(n, k, n_real, k_real)
        x = x * xm[:, None]
        c = c * cm[:, None]
        counts, sums, distortion, assign = model.kmeans_accumulate(x, c, xm, cm)
        wc, ws, wd, wa = kmeans_accumulate_ref(
            x[:n_real], c[:k_real], jnp.ones(n_real), jnp.ones(k_real)
        )
        np.testing.assert_allclose(counts[:k_real], wc, atol=1e-4)
        np.testing.assert_allclose(sums[:k_real], ws, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(distortion, wd, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(
            np.asarray(assign[:n_real]), np.asarray(wa)
        )


class TestRangeCount:
    def test_basic(self):
        x = jnp.asarray([[0.0, 0], [1, 0], [2, 0], [5, 0]], dtype=jnp.float32)
        q = jnp.asarray([[0.0, 0], [5, 0]], dtype=jnp.float32)
        xm = jnp.ones(4)
        r2 = jnp.asarray([1.0 + 1e-6, 0.5], dtype=jnp.float32)
        (counts,) = model.range_count(x, q, xm, r2)
        # q0: points at d2 {0,1,4,25} -> 2 inside; q1: {25,16,9,0} -> 1.
        np.testing.assert_allclose(counts, [2.0, 1.0])

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 8))
    def test_hypothesis_vs_numpy(self, seed, d):
        x, q = _rand((16, d), seed), _rand((8, d), seed + 1)
        xm, _ = _masks(16, 8, 13, 8)
        r2 = jnp.abs(_rand((8,), seed + 2)) * d
        (counts,) = model.range_count(x, q, xm, r2)
        d2 = pairwise_d2_ref(x[:13], q)
        want = np.sum(np.asarray(d2) <= np.asarray(r2)[None, :], axis=0)
        np.testing.assert_allclose(counts, want)


class TestAotLowering:
    """The lowering itself: HLO text must be emitted and parse-safe."""

    def test_lower_smallest_variant(self):
        from compile.aot import lower_variant

        text = lower_variant("pairwise_d2", 256, 128, 8)
        assert "HloModule" in text
        assert "f32[256,8]" in text and "f32[128,8]" in text
        assert "f32[256,128]" in text  # the output tile

    def test_lower_accumulate_outputs(self):
        from compile.aot import lower_variant

        text = lower_variant("kmeans_accumulate", 256, 128, 8)
        assert "HloModule" in text
        # tuple of (counts, sums, distortion, assign)
        assert "f32[128]" in text and "f32[128,8]" in text
        assert "s32[256]" in text

    def test_program_registry_covers_feature_widths(self):
        assert set(model.FEATURE_WIDTHS) == {8, 64, 128, 256, 1024}
        for spec in model.PROGRAMS.values():
            args = spec["args"](model.TILE_N, model.TILE_K, 8)
            assert all(a.dtype in (jnp.float32,) for a in args)
