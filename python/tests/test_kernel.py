"""L1 correctness: Pallas pairwise kernel vs the pure-jnp oracle.

The hypothesis sweep exercises shapes (multiples of the block sizes,
including multi-tile grids), dtypes, and value scales; fixed tests pin
the exact AOT variants that ship in artifacts/.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise import pairwise_d2
from compile.kernels.ref import pairwise_d2_ref


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


@pytest.mark.parametrize("n,k,d", [(256, 128, 8), (256, 128, 64), (512, 256, 8)])
def test_matches_ref_block_shapes(n, k, d):
    x, c = _rand((n, d), seed=1), _rand((k, d), seed=2)
    got = pairwise_d2(x, c)
    want = pairwise_d2_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_matches_ref_large_d():
    # The widest AOT variant (reuters-hashed / gen1000 path).
    x, c = _rand((256, 1024), seed=3), _rand((128, 1024), seed=4)
    np.testing.assert_allclose(
        pairwise_d2(x, c), pairwise_d2_ref(x, c), rtol=1e-4, atol=1e-3
    )


def test_small_blocks_multi_tile_grid():
    # bn/bk much smaller than n/k: a 4 x 4 grid of tiles.
    x, c = _rand((32, 16), seed=5), _rand((32, 16), seed=6)
    got = pairwise_d2(x, c, bn=8, bk=8)
    np.testing.assert_allclose(got, pairwise_d2_ref(x, c), rtol=1e-5, atol=1e-5)


def test_zero_padding_is_exact():
    # The padding contract rust relies on: zero-padding d adds nothing,
    # zero rows give plain squared norms.
    x, c = _rand((16, 6), seed=7), _rand((8, 6), seed=8)
    xp = jnp.pad(x, ((0, 0), (0, 10)))
    cp = jnp.pad(c, ((0, 0), (0, 10)))
    a = pairwise_d2(xp, cp, bn=8, bk=8)
    b = pairwise_d2_ref(x, c)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_identical_points_zero_distance():
    x = _rand((8, 4), seed=9)
    d2 = pairwise_d2(x, x, bn=8, bk=8)
    np.testing.assert_allclose(jnp.diagonal(d2), jnp.zeros(8), atol=1e-4)
    # Clamp guarantees non-negativity even where cancellation bites.
    assert jnp.all(d2 >= 0.0)


def test_nonnegative_under_cancellation():
    # Near-identical large-magnitude points: the expansion form would go
    # negative without the clamp.
    base = _rand((8, 16), scale=1e3, seed=10)
    x = base + 1e-4 * _rand((8, 16), seed=11)
    d2 = pairwise_d2(x, base, bn=8, bk=8)
    assert jnp.all(d2 >= 0.0)


@settings(max_examples=40, deadline=None)
@given(
    tiles_n=st.integers(1, 3),
    tiles_k=st.integers(1, 3),
    bn=st.sampled_from([4, 8, 16]),
    bk=st.sampled_from([4, 8, 16]),
    d=st.integers(1, 40),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(tiles_n, tiles_k, bn, bk, d, scale, seed):
    n, k = tiles_n * bn, tiles_k * bk
    x = _rand((n, d), scale=scale, seed=seed)
    c = _rand((k, d), scale=scale, seed=seed + 1)
    got = pairwise_d2(x, c, bn=bn, bk=bk)
    want = pairwise_d2_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_dtype_promotion(seed):
    # Integer / f64 inputs are accepted and computed in f32.
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-5, 5, size=(8, 3)), dtype=jnp.int32)
    c = jnp.asarray(rng.normal(size=(8, 3)), dtype=jnp.float32)
    got = pairwise_d2(x, c, bn=8, bk=8)
    want = pairwise_d2_ref(x.astype(jnp.float32), c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got.dtype == jnp.float32


def test_rejects_non_multiple_shapes():
    x, c = _rand((10, 4)), _rand((8, 4))
    with pytest.raises(AssertionError):
        pairwise_d2(x, c, bn=8, bk=8)
