"""AOT artifact integrity: every manifest variant exists, parses as HLO
text with the declared parameter/result shapes, and the manifest is
consistent with the program registry. Runs against artifacts/ when built
(``make artifacts``), otherwise lowers a spot-check subset in-process.
"""

import json
import os
import re

import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_covers_all_programs_and_widths(self):
        m = manifest()
        seen = {(v["program"], v["d"]) for v in m["variants"]}
        for program in model.PROGRAMS:
            for d in model.FEATURE_WIDTHS:
                assert (program, d) in seen, f"missing {program} d={d}"

    def test_tiles_match_model_constants(self):
        m = manifest()
        assert m["tile_n"] == model.TILE_N
        assert m["tile_k"] == model.TILE_K
        for v in m["variants"]:
            assert v["n"] == model.TILE_N
            assert v["k"] == model.TILE_K

    def test_files_exist_and_are_hlo_text(self):
        m = manifest()
        for v in m["variants"]:
            path = os.path.join(ART, v["file"])
            assert os.path.exists(path), v["file"]
            with open(path) as f:
                text = f.read()
            assert text.startswith("HloModule"), v["file"]
            assert "ENTRY" in text, v["file"]

    def test_declared_shapes_appear_in_hlo(self):
        m = manifest()
        for v in m["variants"]:
            with open(os.path.join(ART, v["file"])) as f:
                text = f.read()
            n, k, d = v["n"], v["k"], v["d"]
            # Inputs: x[n,d] and c/q[k,d] must appear as parameters.
            assert re.search(rf"f32\[{n},{d}\]", text), f"{v['file']}: no x shape"
            assert re.search(rf"f32\[{k},{d}\]", text), f"{v['file']}: no c shape"
            if v["program"] == "pairwise_d2":
                assert re.search(rf"f32\[{n},{k}\]", text), "no output tile"
            if v["program"] == "kmeans_accumulate":
                assert re.search(rf"s32\[{n}\]", text), "no assign output"

    def test_no_custom_calls(self):
        # interpret=True must have lowered Pallas to plain HLO — a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        m = manifest()
        for v in m["variants"]:
            with open(os.path.join(ART, v["file"])) as f:
                text = f.read()
            assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), (
                f"{v['file']} contains a Mosaic custom-call"
            )


class TestInProcessLowering:
    """Spot-check lowering without requiring artifacts on disk."""

    @pytest.mark.parametrize("program", sorted(model.PROGRAMS))
    def test_lowers_smallest_width(self, program):
        from compile.aot import lower_variant

        text = lower_variant(program, 256, 128, 8)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
