//! Quickstart: the engine facade — build one index over a clustered
//! dataset, then run many queries against it. Exact tree-accelerated
//! K-means is compared with the naive baseline (identical answers, far
//! fewer distance computations), then the same index answers k-NN and
//! anomaly queries without rebuilding anything.
//!
//! Run: `cargo run --release --example quickstart`

use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{
    AnomalyQuery, IndexBuilder, KmeansQuery, KnnQuery, KnnTarget, Query, QueryResult,
};

fn main() {
    // 1. One index: the `cell` surrogate from Table 1 at 10% scale
    //    (≈4000 points × 38 dims, 12 latent clusters), middle-out
    //    anchors-hierarchy tree (§3.1), leaf threshold 30.
    let index = IndexBuilder::new(DatasetSpec::scaled(DatasetKind::Cell, 0.10))
        .rmin(30)
        .build();
    println!(
        "dataset: cell — {} points × {} dims",
        index.space().n(),
        index.space().dim()
    );

    // The tree is built lazily, on the first query that needs it.
    let tree = index.tree();
    let shape = tree.shape();
    println!(
        "tree: {} nodes / {} leaves, depth {}, built with {} distance computations",
        shape.nodes, shape.leaves, shape.max_depth, tree.build_dists
    );
    tree.validate(index.space()).expect("tree invariants");

    // 2. Exact K-means, naive vs tree-accelerated — identical output,
    //    very different cost. Both run through the same dispatcher.
    let k = 12;
    let naive_q = Query::Kmeans(KmeansQuery { k, iters: 10, use_tree: false, ..Default::default() });
    let tree_q = Query::Kmeans(KmeansQuery { k, iters: 10, use_tree: true, ..Default::default() });

    let before = index.dist_count();
    let naive = index.run(&naive_q);
    let naive_dists = index.dist_count() - before;

    let before = index.dist_count();
    let fast = index.run(&tree_q);
    let tree_dists = index.dist_count() - before;

    let (QueryResult::Kmeans { distortion: dn, .. }, QueryResult::Kmeans { distortion: dt, .. }) =
        (&naive, &fast)
    else {
        unreachable!("kmeans queries return kmeans results");
    };
    println!("\nK-means, k={k}, 10 iterations:");
    println!("  naive : distortion {dn:.6e}  {naive_dists:>12} distance computations");
    println!("  tree  : distortion {dt:.6e}  {tree_dists:>12} distance computations");
    println!(
        "  exactness: |Δdistortion| = {:.2e}   speedup: {:.1}×",
        (dn - dt).abs(),
        naive_dists as f64 / tree_dists.max(1) as f64
    );

    // 3. The same index serves other query families — build once, query
    //    many. A whole workload amortizes over one tree via run_batch.
    let results = index.run_batch(&[
        Query::Knn(KnnQuery { target: KnnTarget::Point(0), k: 5, ..Default::default() }),
        Query::Anomaly(AnomalyQuery { threshold: 15, ..Default::default() }),
    ]);
    for r in &results {
        println!("{}", r.summary());
    }
}
