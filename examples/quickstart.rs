//! Quickstart: build a middle-out metric tree over a clustered dataset
//! and run exact tree-accelerated K-means, comparing distance counts with
//! the naive baseline.
//!
//! Run: `cargo run --release --example quickstart`

use anchors_hierarchy::algorithms::kmeans;
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};

fn main() {
    // 1. A dataset: the `cell` surrogate from Table 1 at 10% scale
    //    (≈4000 points × 38 dims, 12 latent clusters).
    let spec = DatasetSpec::scaled(DatasetKind::Cell, 0.10);
    let space = spec.build();
    println!(
        "dataset: {} — {} points × {} dims",
        spec.kind.name(),
        space.n(),
        space.dim()
    );

    // 2. The anchors-hierarchy middle-out metric tree (§3.1 of the paper).
    let tree = middle_out::build(&space, &MiddleOutConfig::default());
    let shape = tree.shape();
    println!(
        "tree: {} nodes / {} leaves, depth {}, built with {} distance computations",
        shape.nodes, shape.leaves, shape.max_depth, tree.build_dists
    );
    tree.validate(&space).expect("tree invariants");

    // 3. Exact K-means, naive vs tree-accelerated — identical output,
    //    very different cost.
    let k = 12;
    let iters = 10;
    let opts = kmeans::KmeansOpts::default();

    let naive = kmeans::naive_lloyd(&space, kmeans::Init::Random, k, iters, &opts);
    let fast = kmeans::tree_lloyd(&space, &tree, kmeans::Init::Random, k, iters, &opts);

    println!("\nK-means, k={k}, {iters} iterations:");
    println!(
        "  naive : distortion {:.6e}  {:>12} distance computations",
        naive.distortion, naive.dists
    );
    println!(
        "  tree  : distortion {:.6e}  {:>12} distance computations",
        fast.distortion, fast.dists
    );
    println!(
        "  exactness: |Δdistortion| = {:.2e}   speedup: {:.1}×",
        (naive.distortion - fast.distortion).abs(),
        naive.dists as f64 / fast.dists as f64
    );

    // 4. Anchors initialization (Table 4): better starting distortion.
    let random_start = kmeans::random_init(&space, k, 1);
    let anchors_start = kmeans::anchors_init(&space, k, 1);
    println!(
        "\ninitialization quality (distortion before any iteration):\n  random  {:.6e}\n  anchors {:.6e}  ({:.2}× better)",
        kmeans::distortion_of(&space, &random_start),
        kmeans::distortion_of(&space, &anchors_start),
        kmeans::distortion_of(&space, &random_start) / kmeans::distortion_of(&space, &anchors_start)
    );
}
