//! Non-parametric anomaly detection (paper §4.2) on the covtype
//! surrogate: flag points whose r-neighborhood holds fewer than t points,
//! exactly, at a fraction of the naive cost — then show the XLA
//! `range_count` artifact answering the same neighborhood counts in
//! batched tiles.
//!
//! Run: `cargo run --release --example anomaly_detection`

use anchors_hierarchy::algorithms::anomaly;
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::runtime::BatchDistanceEngine;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};

fn main() {
    let spec = DatasetSpec::scaled(DatasetKind::Covtype, 0.02);
    let space = spec.build();
    println!(
        "dataset: {} — {} points × {} dims",
        spec.kind.name(),
        space.n(),
        space.dim()
    );

    // Calibrate the radius so ~10% of points are "interesting" anomalies
    // (the paper's §5 protocol).
    let threshold = 15u64;
    let radius = anomaly::calibrate_radius(&space, threshold, 0.10, 60, 1);
    let params = anomaly::AnomalyParams { radius, threshold };
    println!("test: anomalous iff fewer than {threshold} neighbors within r = {radius:.3}");

    let tree = middle_out::build(&space, &MiddleOutConfig::default());

    space.reset_count();
    let naive = anomaly::naive_sweep(&space, &params);
    space.reset_count();
    let fast = anomaly::tree_sweep(&space, &tree, &params);

    assert_eq!(naive.flags, fast.flags, "accelerated result must be exact");
    println!(
        "\nanomalies: {} / {} points ({:.1}%)",
        fast.n_anomalies,
        space.n(),
        100.0 * fast.n_anomalies as f64 / space.n() as f64
    );
    println!(
        "distance computations: naive {}  tree {}  speedup {:.1}×",
        naive.dists,
        fast.dists,
        naive.dists as f64 / fast.dists as f64
    );

    // Bonus: the same neighborhood counts through the AOT-compiled XLA
    // range_count kernel (the L1/L2 layers), checked against the scalar
    // truth for the first few queries.
    match BatchDistanceEngine::open_default() {
        Ok(engine) => {
            let dim = space.dim();
            let width = engine.width_for("range_count", dim).unwrap();
            let (tn, tk) = (engine.manifest().tile_n, engine.manifest().tile_k);
            let nq = 8usize;
            // Tile 0..tn dataset rows (enough for a demo) against nq queries.
            let n_rows = space.n().min(tn);
            let mut x = vec![0f32; tn * width];
            let mut xmask = vec![0f32; tn];
            for i in 0..n_rows {
                space.fill_row(i, &mut x[i * width..(i + 1) * width]);
                xmask[i] = 1.0;
            }
            let mut q = vec![0f32; tk * width];
            let mut r2 = vec![0f32; tk];
            for j in 0..nq {
                space.fill_row(j, &mut q[j * width..(j + 1) * width]);
                r2[j] = (radius * radius) as f32;
            }
            let counts = engine
                .with_engine(|e| e.range_count_tile(width, &x, &q, &xmask, &r2))
                .expect("range_count tile");
            println!("\nXLA range_count artifact (first {nq} queries, first {n_rows} rows):");
            for j in 0..nq {
                let manual = (0..n_rows)
                    .filter(|&i| space.dist_uncounted(i, j) <= radius)
                    .count();
                println!(
                    "  query {j}: xla count {:>4}  scalar count {:>4}  {}",
                    counts[j] as usize,
                    manual,
                    if counts[j] as usize == manual { "✓" } else { "✗ MISMATCH" }
                );
                assert_eq!(counts[j] as usize, manual);
            }
        }
        Err(e) => println!("\n(XLA demo skipped: {e})"),
    }
}
