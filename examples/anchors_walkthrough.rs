//! Walkthrough of Figures 2–10: the anchors hierarchy on 2-d points,
//! then the middle-out agglomeration, traced step by step in ASCII.
//!
//! Run: `cargo run --release --example anchors_walkthrough`

use anchors_hierarchy::anchors::build_anchors;
use anchors_hierarchy::data::{Data, DenseMatrix};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};

/// Render 2-d points as a terminal scatter plot, labelling each point
/// with the id of its owning anchor.
fn plot(space: &Space, owner: &[usize], width: usize, height: usize) {
    let n = space.n();
    let (mut xlo, mut xhi, mut ylo, mut yhi) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    let mut row = vec![0f32; 2];
    let mut coords = Vec::with_capacity(n);
    for i in 0..n {
        space.fill_row(i, &mut row);
        let (x, y) = (row[0] as f64, row[1] as f64);
        xlo = xlo.min(x);
        xhi = xhi.max(x);
        ylo = ylo.min(y);
        yhi = yhi.max(y);
        coords.push((x, y));
    }
    let mut grid = vec![vec![' '; width]; height];
    for (i, &(x, y)) in coords.iter().enumerate() {
        let gx = ((x - xlo) / (xhi - xlo + 1e-9) * (width - 1) as f64) as usize;
        let gy = ((y - ylo) / (yhi - ylo + 1e-9) * (height - 1) as f64) as usize;
        let ch = char::from_digit((owner[i] % 36) as u32, 36).unwrap_or('*');
        grid[height - 1 - gy][gx] = ch;
    }
    for line in grid {
        println!("  {}", line.iter().collect::<String>());
    }
}

fn main() {
    // Figure 2: a set of points in 2-d — three blobs plus scatter.
    let mut rng = Rng::new(42);
    let mut rows = Vec::new();
    for (cx, cy) in [(-8.0, -3.0), (6.0, 5.0), (0.0, 9.0)] {
        for _ in 0..60 {
            rows.push(vec![
                (cx + rng.normal() * 1.5) as f32,
                (cy + rng.normal() * 1.5) as f32,
            ]);
        }
    }
    for _ in 0..30 {
        rows.push(vec![
            rng.uniform(-12.0, 12.0) as f32,
            rng.uniform(-8.0, 12.0) as f32,
        ]);
    }
    let space = Space::euclidean(Data::Dense(DenseMatrix::from_rows(&rows)));
    println!("Figures 2-6: growing the anchor set (each digit = owning anchor)\n");

    // Figures 3, 5, 6: anchor sets of growing size. Each point labelled by
    // its owner; watch new anchors claim the Voronoi-vertex regions.
    for k in [3usize, 4, 8] {
        space.reset_count();
        let set = build_anchors(
            &space,
            &(0..space.n() as u32).collect::<Vec<_>>(),
            k,
            &mut Rng::new(7),
        );
        let mut owner = vec![0usize; space.n()];
        for (ai, a) in set.anchors.iter().enumerate() {
            for &(_, p) in &a.owned {
                owner[p as usize] = ai;
            }
        }
        println!(
            "k = {k}: {} distance computations (brute force would be {})",
            space.dist_count(),
            space.n() * k
        );
        plot(&space, &owner, 68, 20);
        for (ai, a) in set.anchors.iter().enumerate() {
            println!(
                "  anchor {ai}: pivot point #{:<4} radius {:>7.3}  owns {:>3}",
                a.pivot,
                a.radius(),
                a.len()
            );
        }
        println!();
    }

    // Figures 7-10: the middle-out tree. Show the merge structure levels.
    println!("Figures 7-10: middle-out agglomeration into a metric tree\n");
    let tree =
        middle_out::build(&space, &MiddleOutConfig { rmin: 12, seed: 7, ..Default::default() });
    tree.validate(&space).expect("valid tree");
    let shape = tree.shape();
    println!(
        "tree: {} nodes, {} leaves, depth {}, build {} dists",
        shape.nodes, shape.leaves, shape.max_depth, tree.build_dists
    );
    // Print the top 3 levels of the merge tree with ball stats.
    fn show(tree: &anchors_hierarchy::tree::MetricTree, id: u32, depth: usize, max_depth: usize) {
        let n = tree.node(id);
        println!(
            "  {}{} r={:<8.3} count={:<4} {}",
            "    ".repeat(depth),
            if n.is_leaf() { "leaf" } else { "node" },
            n.radius,
            n.count,
            if depth == max_depth && !n.is_leaf() { "…" } else { "" }
        );
        if depth < max_depth {
            if let Some((a, b)) = n.children {
                show(tree, a, depth + 1, max_depth);
                show(tree, b, depth + 1, max_depth);
            }
        }
    }
    show(&tree, tree.root, 0, 3);
}
