//! Attribute grouping (paper §4.3): transpose the dataset, standardize,
//! and find all pairs of attributes with correlation ≥ ρ via the
//! correlation ↔ distance identity ρ = 1 − D²/2 — plus the §6 extension:
//! a dependency tree (maximum-correlation spanning tree) over attributes
//! via the dual-tree MST.
//!
//! Run: `cargo run --release --example attribute_grouping`

use anchors_hierarchy::algorithms::{allpairs, mst};
use anchors_hierarchy::data::{Data, DenseMatrix};
use anchors_hierarchy::metrics::Space;
use anchors_hierarchy::rng::Rng;
use anchors_hierarchy::tree::middle_out::{self, MiddleOutConfig};

/// Build a dataset with planted attribute-correlation structure: groups
/// of attributes driven by shared latent factors plus noise columns.
fn build_data(rows: usize, seed: u64) -> (DenseMatrix, Vec<(usize, usize)>) {
    let mut rng = Rng::new(seed);
    // 4 latent factors; attribute groups of 3 tied to each; 8 noise attrs.
    let n_factors = 4;
    let per_group = 3;
    let noise_attrs = 8;
    let d = n_factors * per_group + noise_attrs;
    let mut expected = Vec::new();
    for g in 0..n_factors {
        for a in 0..per_group {
            for b in (a + 1)..per_group {
                expected.push((g * per_group + a, g * per_group + b));
            }
        }
    }
    let mut values = Vec::with_capacity(rows * d);
    for _ in 0..rows {
        let factors: Vec<f64> = (0..n_factors).map(|_| rng.normal()).collect();
        for g in 0..n_factors {
            for _ in 0..per_group {
                values.push((factors[g] + 0.25 * rng.normal()) as f32);
            }
        }
        for _ in 0..noise_attrs {
            values.push(rng.normal() as f32);
        }
    }
    (DenseMatrix::new(rows, d, values), expected)
}

fn main() {
    let (data, expected) = build_data(2000, 3);
    println!(
        "dataset: {} records × {} attributes (4 latent factor groups of 3 + 8 noise)",
        data.n, data.d
    );

    // --- correlated pairs at ρ ≥ 0.9 -----------------------------------
    let rho = 0.90;
    let (pairs_tree, dists_tree) = allpairs::correlated_attribute_pairs(&data, rho, 4, true);
    let (pairs_naive, dists_naive) = allpairs::correlated_attribute_pairs(&data, rho, 4, false);
    println!("\nattribute pairs with ρ ≥ {rho}:");
    for &(i, j, r) in &pairs_tree {
        let planted = expected.contains(&(i as usize, j as usize));
        println!("  attr{i:<3} ~ attr{j:<3}  ρ = {r:.4}  {}", if planted { "(planted)" } else { "" });
    }
    assert_eq!(
        pairs_tree.iter().map(|&(i, j, _)| (i, j)).collect::<Vec<_>>(),
        pairs_naive.iter().map(|&(i, j, _)| (i, j)).collect::<Vec<_>>(),
        "dual-tree and naive must agree"
    );
    let found: Vec<(usize, usize)> = pairs_tree
        .iter()
        .map(|&(i, j, _)| (i as usize, j as usize))
        .collect();
    for e in &expected {
        assert!(found.contains(e), "planted pair {e:?} missed");
    }
    println!(
        "all {} planted pairs found; 0 false positives among noise attrs: {}",
        expected.len(),
        found.iter().all(|&(i, j)| i < 12 && j < 12)
    );
    println!(
        "distance computations: naive {dists_naive}  dual-tree {dists_tree}  speedup {:.1}×",
        dists_naive as f64 / dists_tree as f64
    );

    // --- dependency tree over attributes (§6) ---------------------------
    let attrs = allpairs::attribute_view(&data);
    let space = Space::euclidean(Data::Dense(attrs));
    let tree = middle_out::build(&space, &MiddleOutConfig { rmin: 4, ..Default::default() });
    let edges = mst::tree_mst(&space, &tree);
    println!("\ndependency tree (max-correlation spanning tree over attributes):");
    let mut grouped_edges = 0;
    for e in &edges {
        let rho = allpairs::tau_to_rho(e.dist);
        let same_group = (e.a as usize / 3 == e.b as usize / 3) && e.a < 12 && e.b < 12;
        if same_group {
            grouped_edges += 1;
        }
        println!(
            "  attr{:<3} — attr{:<3}  ρ = {rho:+.4}{}",
            e.a,
            e.b,
            if same_group { "  [intra-group]" } else { "" }
        );
    }
    // Every factor group of 3 should be internally connected: 2 intra-group
    // edges per group = 8.
    println!("intra-group edges: {grouped_edges} (expected 8)");
    assert_eq!(grouped_edges, 8, "dependency tree missed factor structure");
}
