//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! This example proves all layers compose:
//!   L1/L2 — the AOT-compiled Pallas/JAX pairwise-distance artifacts are
//!           loaded through PJRT and used on the K-means hot path;
//!   L3    — the batch coordinator serves a mixed workload of clustering,
//!           anomaly-detection and all-pairs jobs over four Table-1
//!           datasets, tree-accelerated, with exact distance accounting.
//!
//! It finishes by reporting the paper's headline metric — distance-
//! computation speedup of the cached-statistics metric tree over the
//! naive baselines — for every job pair, plus coordinator throughput.
//!
//! Run: `cargo run --release --example end_to_end`
//! (recorded in EXPERIMENTS.md §End-to-end)

use anchors_hierarchy::coordinator::{Coordinator, JobSpec, JobState};
use anchors_hierarchy::dataset::{DatasetKind, DatasetSpec};
use anchors_hierarchy::engine::{AnomalyQuery, InitKind, KmeansQuery, Query, QueryResult};
use anchors_hierarchy::runtime::BatchDistanceEngine;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let scale = std::env::var("E2E_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05f64);
    let seed = 20130u64;

    // L1/L2: the XLA batch engine over the AOT artifacts.
    let engine = match BatchDistanceEngine::open_default() {
        Ok(e) => {
            println!(
                "XLA engine: artifacts loaded (pairwise widths {:?})",
                e.manifest().widths("pairwise_d2")
            );
            Some(Arc::new(e))
        }
        Err(e) => {
            println!("XLA engine unavailable ({e}); running scalar-only");
            None
        }
    };

    // L3: the coordinator.
    let coord = Coordinator::with_engine(4, 64, engine);
    let datasets = [
        DatasetKind::Squiggles,
        DatasetKind::Cell,
        DatasetKind::Covtype,
        DatasetKind::Reuters { half: false },
    ];
    println!(
        "\nworkload: k-means + anomalies + all-pairs on {:?} at scale {scale}\n",
        datasets.iter().map(|d| d.name()).collect::<Vec<_>>()
    );

    let t0 = Instant::now();
    // For each dataset, submit (naive, tree) pairs of each operation —
    // the same typed engine queries the CLI and TCP server construct.
    let mut handles: Vec<(String, String, bool, u64)> = Vec::new();
    for kind in &datasets {
        let dataset = DatasetSpec { kind: kind.clone(), scale, seed };
        for (opname, use_tree) in
            [("kmeans-k20", false), ("kmeans-k20", true), ("anomalies", false), ("anomalies", true)]
        {
            let query = match opname {
                "kmeans-k20" => Query::Kmeans(KmeansQuery {
                    k: 20,
                    iters: 5,
                    init: InitKind::Anchors,
                    use_tree,
                }),
                _ => Query::Anomaly(AnomalyQuery {
                    threshold: 15,
                    radius: None,
                    target_frac: 0.1,
                    use_tree,
                }),
            };
            let spec = JobSpec { dataset: dataset.clone(), query, rmin: 30 };
            let id = coord.submit(spec).expect("queue sized for workload");
            handles.push((kind.name(), opname.to_string(), use_tree, id));
        }
    }

    // Collect and pair up.
    let mut results: std::collections::HashMap<(String, String, bool), (u64, QueryResult, f64)> =
        std::collections::HashMap::new();
    for (ds, op, tree, id) in &handles {
        match coord.wait(*id) {
            JobState::Done(r) => {
                results.insert((ds.clone(), op.clone(), *tree), (r.dists, r.output, r.wall_ms));
            }
            JobState::Failed(e) => panic!("job {ds}/{op} failed: {e}"),
            _ => unreachable!(),
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "{:<12} {:<12} {:>14} {:>14} {:>9}  result",
        "dataset", "operation", "naive dists", "tree dists", "speedup"
    );
    let mut speedups = Vec::new();
    for kind in &datasets {
        for op in ["kmeans-k20", "anomalies"] {
            let naive = &results[&(kind.name(), op.to_string(), false)];
            let tree = &results[&(kind.name(), op.to_string(), true)];
            let speedup = naive.0 as f64 / tree.0.max(1) as f64;
            speedups.push((kind.name(), op, speedup));
            // Exactness across the pair where the outputs are comparable.
            match (&naive.1, &tree.1) {
                (
                    QueryResult::Kmeans { distortion: a, .. },
                    QueryResult::Kmeans { distortion: b, .. },
                ) => assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                    "{} kmeans mismatch: {a} vs {b}",
                    kind.name()
                ),
                (
                    QueryResult::Anomaly { anomalies: a, .. },
                    QueryResult::Anomaly { anomalies: b, .. },
                ) => assert_eq!(a, b, "{} anomaly mismatch", kind.name()),
                _ => {}
            }
            println!(
                "{:<12} {:<12} {:>14} {:>14} {:>8.1}×  {}",
                kind.name(),
                op,
                naive.0,
                tree.0,
                speedup,
                tree.1.summary()
            );
        }
    }

    let m = coord.shutdown();
    println!(
        "\ncoordinator: {} jobs in {wall:.1}s ({:.1} jobs/s), {} total distance computations",
        m.completed,
        m.completed as f64 / wall,
        m.total_dists
    );

    // Headline assertions: structured data accelerates, reuters does not
    // (the paper's central qualitative claims).
    let get = |ds: &str, op: &str| {
        speedups
            .iter()
            .find(|(d, o, _)| d == ds && *o == op)
            .map(|(_, _, s)| *s)
            .unwrap()
    };
    assert!(
        get("squiggles", "kmeans-k20") > 3.0,
        "2-d structured data must accelerate"
    );
    assert!(
        get("cell", "kmeans-k20") > 1.5,
        "38-d clustered data must accelerate"
    );
    let reuters = get("reuters100", "kmeans-k20");
    assert!(
        reuters < 2.0,
        "reuters is supposed to show little-to-anti speedup, got {reuters}"
    );
    println!("\nheadline checks passed: structure ⇒ speedup, reuters ⇒ none (paper §5, §7)");
}
